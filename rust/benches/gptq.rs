//! Bench: QuantLM construction (§4.2) — GPTQ per-matrix wall clock at
//! realistic layer shapes, plus the accuracy story: GPTQ vs
//! round-to-nearest on the Hessian-weighted objective.

use spectra::gptq::{gptq_quantize, hessian_weighted_error, GptqConfig,
                    HessianAccumulator};
use spectra::quant::QuantTensor;
use spectra::runtime::{HostTensor, SplitMix64};
use spectra::util::bench::{bench_few, black_box};

fn correlated_inputs(n: usize, d: usize, seed: u64) -> HostTensor {
    let mut rng = SplitMix64::new(seed);
    let mut data = Vec::with_capacity(n * d);
    for _ in 0..n {
        let base = rng.next_gaussian();
        for j in 0..d {
            data.push((0.6 * base + 0.4 * rng.next_gaussian()
                + if j % 5 == 0 { 0.4 * base } else { 0.0 }) as f32);
        }
    }
    HostTensor::new(vec![n, d], data)
}

fn main() {
    println!("== gptq: QuantLM construction cost & quality ==");
    for (rows, cols) in [(256, 256), (704, 256), (384, 1056)] {
        let w = HostTensor::randn(vec![rows, cols], 0.05, 7);
        let x = correlated_inputs(512, cols, 8);
        let mut acc = HessianAccumulator::new(cols);
        acc.add_batch(&x);
        let h = acc.finalize();

        // group must divide in_features (suite layers use the largest
        // divisor <= 128, e.g. 96 for glu = 1056).
        let group = spectra::gptq::pipeline::largest_divisor(cols, 128);
        let cfg = GptqConfig::new(4, group);
        let r = bench_few(&format!("gptq_4bit_{rows}x{cols}"), 3, || {
            black_box(gptq_quantize(&w, &h, cfg).unwrap());
        });
        r.report_throughput("weights", (rows * cols) as f64);

        let gptq = gptq_quantize(&w, &h, cfg).unwrap();
        let rtn = QuantTensor::quantize_rtn(&w, 4, group);
        let (eg, er) = (hessian_weighted_error(&w, &gptq, &h),
                        hessian_weighted_error(&w, &rtn, &h));
        println!("  H-weighted err: GPTQ {eg:.4e} vs RTN {er:.4e} \
                  (GPTQ wins by {:.1}%)\n", 100.0 * (er - eg) / er);
    }

    // Hessian accumulation throughput (the capture-side cost).
    let x = correlated_inputs(1024, 256, 9);
    let mut acc = HessianAccumulator::new(256);
    bench_few("hessian_add_batch_1024x256", 5, || {
        acc.add_batch(&x);
    }).report_throughput("activations", (1024 * 256) as f64);
}
