//! Bench: end-to-end train-step wall clock per size x family through the
//! PJRT runtime — the Fig. 8 throughput axis and the L3 §Perf target
//! (dispatch overhead must be small vs graph execution).
//!
//! Requires `make artifacts`. Skips silently if artifacts are missing.

use spectra::config::{Family, TrainConfig};
use spectra::coordinator::Trainer;
use spectra::data::{Batcher, Dataset};
use spectra::runtime::Runtime;
use spectra::util::bench::bench_few;

fn main() {
    let Ok(rt) = Runtime::new("artifacts") else {
        println!("train_step: artifacts/ missing, run `make artifacts`");
        return;
    };
    let data = Dataset::build(std::path::Path::new("runs/data"), 400_000, 0)
        .expect("dataset");

    for (size, family, iters) in [("160k", Family::Float, 10),
                                  ("160k", Family::Ternary, 10),
                                  ("430k", Family::Ternary, 6),
                                  ("930k", Family::Ternary, 4)] {
        let model = format!("{size}_{}", family.as_str());
        let cfg = TrainConfig::for_family(family, 1000);
        let Ok(mut trainer) = Trainer::new(&rt, &model, cfg) else {
            continue;
        };
        let mut batcher = Batcher::new(data.train.clone(),
                                       rt.manifest().train_batch,
                                       rt.manifest().seq, 0);
        let tokens_per_step =
            rt.manifest().train_batch * rt.manifest().seq;
        let r = bench_few(&format!("train_step_{model}"), iters, || {
            let batch = batcher.next_batch();
            trainer.step(&batch).expect("step");
        });
        r.report_throughput("tokens", tokens_per_step as f64);
    }

    // Dispatch overhead proxy: batcher + literal assembly without execute.
    let mut batcher = Batcher::new(data.train.clone(), 8, 128, 0);
    bench_few("batcher_next_batch", 200, || {
        std::hint::black_box(batcher.next_batch());
    }).report();
}
