//! Cross-family speculative decoding acceptance harness: TriLM drafts,
//! any family verifies — proven bitwise-lossless.
//!
//! The claim under test is the tentpole's: draft-verify decoding
//! ([`Scheduler::set_speculative`]) is an *operational* optimization,
//! never a semantic one. The target verifies each draft proposal batch
//! in one chunked pass and every emitted token is sampled from the
//! target's own logits, in stream order, with the lane's own RNG — so
//! the stream a speculative lane delivers must be bitwise identical to
//! plain target-only decode, for every storage family the engine
//! serves (FloatLM f32, QuantLM RTN/GPTQ, TriLM ternary), at every
//! draft depth k, under greedy and seeded top-k sampling alike, and
//! across KV-backpressure requeue bounces
//! ([`FaultPlan::out_of_pages_steps`] forces those deterministically).
//!
//! The harness also pins the accounting contract: `spec_proposed` /
//! `spec_accepted` count *delivered* work only (rolled back with the
//! stream when a lane bounces), while `spec_verify_steps` counts
//! executed verify rounds — and a forced out-of-pages refusal landing
//! mid-verify must hand back every page of *both* KV caches.

use spectra::serve::{DecodeModel, FamilySpec, FaultPlan, GenRequest,
                     LatentAttnLm, LmDims, QuantMethod, Sampling, Scheduler,
                     SpecConfig};

fn dims() -> LmDims {
    LmDims { vocab: 128, hidden: 64, glu: 96, layers: 3 }
}

/// The four target families of the acceptance bar. Group 128 at these
/// dims exercises the ragged-group path; GPTQ covers the calibrated
/// quantizer.
fn four_targets() -> [FamilySpec; 4] {
    [
        FamilySpec::Float,
        FamilySpec::Quant { bits: 3, group: 128, method: QuantMethod::Rtn },
        FamilySpec::Quant { bits: 4, group: 128, method: QuantMethod::Gptq },
        FamilySpec::Ternary,
    ]
}

fn request_set() -> Vec<GenRequest> {
    (0..12).map(|id| {
        let prompt: Vec<u32> = (0..(1 + id % 5))
            .map(|j| ((7 * id + 3 * j) % 128) as u32)
            .collect();
        GenRequest::greedy(id, prompt, 4 + id % 7)
    }).collect()
}

/// Cache capacity: request_set() lanes commit at most prompt (5) +
/// max_new (10) - 1 = 14 positions, and the scheduler clamps proposals
/// by the remaining budget so a verify round's transient claim stays
/// inside the same bound — 16 per lane is headroom, not a requirement.
const CTX: usize = 16;

/// Run `reqs` through `sched` and return the token streams sorted by
/// request id (speculation changes retirement order, never content).
fn run_sorted<M: DecodeModel + ?Sized>(sched: &mut Scheduler<M>,
                                       reqs: Vec<GenRequest>) -> Vec<Vec<u32>> {
    for r in reqs {
        sched.submit(r);
    }
    let mut done = sched.run();
    done.sort_by_key(|c| c.id);
    done.into_iter().map(|c| c.tokens).collect()
}

#[test]
fn all_four_targets_are_bitwise_lossless_at_every_k() {
    // TriLM drafts for a float, RTN-quant, GPTQ-quant, and ternary
    // target; spec-k 1 (minimal), 3 (typical), 8 (beyond most budgets,
    // so the budget clamp is load-bearing). Streams must be bitwise
    // identical to plain decode in all 12 cells.
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 60);
    let draft = latent.build_ternary(8, CTX);
    for spec in four_targets() {
        let target = latent.build(spec, 8, CTX).unwrap();
        let plain = {
            let mut sched = Scheduler::new(target.as_ref(), 4, 2);
            run_sorted(&mut sched, request_set())
        };
        assert_eq!(plain.len(), 12, "{}", spec.label());
        assert_eq!(target.kv_pages_in_use(), 0);
        for k in [1usize, 3, 8] {
            let mut sched = Scheduler::new(target.as_ref(), 4, 2);
            sched.set_speculative(&draft, SpecConfig {
                draft_family: FamilySpec::Ternary, k });
            let got = run_sorted(&mut sched, request_set());
            let st = sched.stats().clone();
            assert_eq!(got, plain,
                       "{} target, k={k}: speculative stream diverged \
                        from plain decode", spec.label());
            assert!(st.spec_proposed > 0,
                    "{} target, k={k}: draft never proposed",
                    spec.label());
            assert!(st.spec_accepted <= st.spec_proposed);
            assert!(st.spec_verify_steps > 0);
            assert!(st.accepted_per_step() <= k as f64 + 1e-12,
                    "{} target, k={k}: accepted/step {} above k",
                    spec.label(), st.accepted_per_step());
            assert_eq!(target.kv_pages_in_use(), 0,
                       "{} target, k={k}: target leaked pages",
                       spec.label());
            assert_eq!(draft.kv_pages_in_use(), 0,
                       "{} target, k={k}: draft leaked pages",
                       spec.label());
        }
    }
}

#[test]
fn acceptance_counters_track_delivered_work_only() {
    // A forced all-lane KV refusal bounces every live lane mid-flight;
    // the replayed decode is deterministic, so once everything
    // completes the *delivered* speculative counters must equal the
    // clean run's exactly — proposals whose stream was thrown away
    // were rolled back with it. Executed work is a different ledger:
    // the bounced run pays extra verify rounds re-deriving the
    // discarded tokens.
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 61);
    let target = latent.build_float(8, CTX);
    let draft = latent.build_ternary(8, CTX);
    let spec = SpecConfig { draft_family: FamilySpec::Ternary, k: 3 };

    let mut clean_sched = Scheduler::new(&target, 4, 2);
    clean_sched.set_speculative(&draft, spec);
    let clean = run_sorted(&mut clean_sched, request_set());
    let clean_st = clean_sched.stats().clone();
    drop(clean_sched);

    let mut sched = Scheduler::new(&target, 4, 2);
    sched.set_speculative(&draft, spec);
    sched.set_fault_plan(FaultPlan {
        out_of_pages_steps: vec![4],
        ..FaultPlan::default()
    });
    let bounced = run_sorted(&mut sched, request_set());
    let st = sched.stats().clone();

    assert_eq!(bounced, clean,
               "a requeue bounce must replay identical streams");
    assert!(st.requeued > 0, "the forced refusal must actually bounce");
    assert_eq!(st.spec_proposed, clean_st.spec_proposed,
               "delivered proposals must not count discarded attempts");
    assert_eq!(st.spec_accepted, clean_st.spec_accepted,
               "delivered acceptances must not count discarded attempts");
    assert_eq!(st.generated_tokens, clean_st.generated_tokens,
               "delivered tokens roll back with the bounce");
    assert!(st.spec_verify_steps >= clean_st.spec_verify_steps,
            "executed verify rounds include the replayed work \
             ({} < {})", st.spec_verify_steps, clean_st.spec_verify_steps);
    assert_eq!(target.kv_pages_in_use(), 0);
    assert_eq!(draft.kv_pages_in_use(), 0);
}

#[test]
fn forced_out_of_pages_mid_verify_returns_every_page_of_both_caches() {
    // Repeated scripted refusals land while lanes hold verify-span
    // claims in the target cache and proposal feeds in the draft cache;
    // every bounce must hand back both, the drain must complete every
    // request bitwise-correctly, and nothing may be left allocated.
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 62);
    let target = latent.build_ternary(8, CTX);
    let draft = latent.build_ternary(8, CTX);
    let spec = SpecConfig { draft_family: FamilySpec::Ternary, k: 3 };

    let plain = {
        let mut sched = Scheduler::new(&target, 4, 2);
        run_sorted(&mut sched, request_set())
    };
    let mut sched = Scheduler::new(&target, 4, 2);
    sched.set_speculative(&draft, spec);
    sched.set_fault_plan(FaultPlan {
        out_of_pages_steps: vec![2, 5, 9],
        ..FaultPlan::default()
    });
    let got = run_sorted(&mut sched, request_set());
    let st = sched.stats().clone();
    assert_eq!(got, plain,
               "streams must survive mid-verify refusals bitwise");
    assert!(st.requeued > 0);
    assert_eq!(target.kv_pages_in_use(), 0,
               "target pages leaked across forced mid-verify refusals");
    assert_eq!(draft.kv_pages_in_use(), 0,
               "draft pages leaked across forced mid-verify refusals");
}

#[test]
fn seeded_top_k_is_bitwise_stable_across_batch_and_bounce() {
    // Sampling under temperature with a per-request seed: the verify
    // walk consumes the lane's RNG once per emitted token in stream
    // order — exactly like plain decode — so seeded top-k speculative
    // streams must match plain top-k decode bitwise, at batch 1/4/8,
    // and across a requeue bounce (the restart re-seeds the RNG, so
    // the replay re-draws the identical sample sequence).
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 63);
    let target = latent.build_float(8, CTX);
    let draft = latent.build_ternary(8, CTX);
    let spec = SpecConfig { draft_family: FamilySpec::Ternary, k: 3 };
    let reqs = || -> Vec<GenRequest> {
        (0..10).map(|id| GenRequest::top_k(
            id, vec![(id as u32) % 128, 9, 41], 6, 5, 0.9,
            1000 + id as u64)).collect()
    };
    for r in reqs() {
        assert!(matches!(r.sampling, Sampling::TopK { .. }));
    }

    let plain = {
        let mut sched = Scheduler::new(&target, 4, 2);
        run_sorted(&mut sched, reqs())
    };
    for max_batch in [1usize, 4, 8] {
        let mut sched = Scheduler::new(&target, max_batch, 2);
        sched.set_speculative(&draft, spec);
        let got = run_sorted(&mut sched, reqs());
        assert_eq!(got, plain,
                   "speculative top-k diverged at batch {max_batch}");
        assert_eq!(target.kv_pages_in_use(), 0);
        assert_eq!(draft.kv_pages_in_use(), 0);
    }
    let mut sched = Scheduler::new(&target, 4, 2);
    sched.set_speculative(&draft, spec);
    sched.set_fault_plan(FaultPlan {
        out_of_pages_steps: vec![3],
        ..FaultPlan::default()
    });
    let got = run_sorted(&mut sched, reqs());
    assert!(sched.stats().requeued > 0);
    assert_eq!(got, plain,
               "a requeue bounce must not perturb the seeded sample \
                sequence");
}
