//! Integration: the QuantLM pipeline over the real capture graph —
//! Hessian accumulation, GPTQ quantization, and the §4.2 quality
//! ordering (8-bit ~ lossless > 4-bit > 3-bit).

use spectra::config::{Family, TrainConfig};
use spectra::coordinator::Trainer;
use spectra::data::{Batcher, Dataset};
use spectra::eval::Evaluator;
use spectra::gptq;
use spectra::runtime::{self, Runtime};

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

#[test]
fn gptq_pipeline_quality_ordering() {
    let Some(rt) = runtime() else { return };
    let data = Dataset::build(std::path::Path::new("runs/data_test"),
                              300_000, 7).unwrap();
    // Briefly train a FloatLM so weights/activations are non-degenerate.
    let cfg = TrainConfig { seed: 7, ..TrainConfig::for_family(Family::Float, 30) };
    let mut trainer = Trainer::new(&rt, "160k_float", cfg).unwrap();
    let mut batcher = Batcher::new(data.train.clone(),
                                   rt.manifest().train_batch,
                                   rt.manifest().seq, 7);
    trainer.train(&mut batcher, 30, |_| {}).unwrap();
    let params = trainer.params().unwrap();

    // Calibration batches + Hessians via the capture graph.
    let b = rt.manifest().capture_batch;
    let s = rt.manifest().seq;
    let mut cal_batcher = Batcher::new(data.train.clone(), b, s - 1, 11);
    let batches: Vec<Vec<i32>> = (0..3).map(|_| cal_batcher.next_batch())
        .collect();
    let hessians = gptq::accumulate_hessians(
        &rt, "160k_float", trainer.param_literals(), &batches).unwrap();
    assert!(hessians.iter().all(|h| h.n_samples == 3 * b * s));
    // Hessian diagonals are non-negative (sum of squares).
    for h in &hessians {
        let hh = h.finalize();
        for j in 0..h.dim {
            assert!(hh[j * h.dim + j] >= 0.0);
        }
    }

    // Quantize at 3/4/8 bits and check the val-nll quality ordering.
    let ev = Evaluator::new(&rt, "160k_float").unwrap();
    let base_lits: Vec<xla::Literal> = params.iter()
        .map(runtime::literal_from_tensor)
        .collect::<Result<_, _>>().unwrap();
    let base = ev.nll(&base_lits, &data.val).unwrap();

    let mut nlls = Vec::new();
    for bits in [8u32, 4, 3] {
        let qm = gptq::quantize_model(&rt, "160k_float", &params, &hessians,
                                      bits, 128).unwrap();
        let lits: Vec<xla::Literal> = qm.params.iter()
            .map(runtime::literal_from_tensor)
            .collect::<Result<_, _>>().unwrap();
        nlls.push((bits, ev.nll(&lits, &data.val).unwrap()));
    }
    let get = |b: u32| nlls.iter().find(|(x, _)| *x == b).unwrap().1;
    // 8-bit is near-lossless.
    assert!((get(8) - base).abs() < 0.02, "8-bit {} vs base {base}", get(8));
    // Degradation grows as bits shrink (allowing tiny noise at this scale).
    assert!(get(3) >= get(4) - 0.005, "3-bit {} vs 4-bit {}", get(3), get(4));
    assert!(get(4) >= get(8) - 0.005);
}
