//! Prefix-shared copy-on-write KV pages, end to end: N lanes serving
//! prompts with a common prefix hold the shared pages *once*
//! physically, diverge through claim-time copy-on-write without ever
//! touching a sibling's reads, and release everything through the
//! refcounted free path — while the scheduler's backpressure machinery
//! (requeue, eviction-before-requeue, stall/sizing guards) stays
//! correct with pinned cache pages in the pool.
//!
//! The correctness heart: a prefix pin is a *cache*, not a
//! reservation. Under KV backpressure the scheduler evicts pins before
//! any lane is requeued, so page pressure caused by cached prefixes is
//! recoverable and must never trip the "cache smaller than a single
//! request" sizing panic or the consecutive-stall guard. And reuse is
//! an operational optimization only: a prefix-hit lane's token stream
//! is bitwise identical to a cold full-prefill decode, in every
//! storage family (`tests/serve_determinism.rs` is the no-sharing
//! baseline this file extends).

use spectra::serve::{DecodeModel, FamilySpec, GenRequest, KvCache,
                     LatentAttnLm, LmDims, QuantMethod, Scheduler,
                     KV_PAGE_TOKENS};

fn dims() -> LmDims {
    LmDims { vocab: 128, hidden: 64, glu: 96, layers: 3 }
}

/// `n` requests with `total`-token prompts whose first `shared` tokens
/// are one fixed sequence and whose tail is per-request —
/// `bench_requests_shared` in miniature, with hand-rolled tokens so
/// the divergence point is explicit. Request 0 is the donor whose
/// prefill seeds the prefix cache.
fn shared_requests(n: usize, shared: usize, total: usize,
                   max_new: usize) -> Vec<GenRequest> {
    (0..n).map(|id| {
        let prompt: Vec<u32> = (0..total).map(|j| {
            if j < shared {
                ((3 * j + 11) % 128) as u32
            } else {
                ((7 * id + 5 * j + 1) % 128) as u32
            }
        }).collect();
        GenRequest::greedy(id, prompt, max_new)
    }).collect()
}

/// Acceptance (a) + (d): lanes sharing a 20-of-24-token prefix map the
/// pinned pages instead of claiming fresh ones — `ceil(P /
/// page_tokens)` physical pages held once, not once per lane — CoW
/// fires exactly once per diverging lane, and the refcounted free path
/// returns every page when lanes retire and the pin is released.
#[test]
fn shared_prefix_holds_physical_pages_once_across_lanes() {
    assert_eq!(KV_PAGE_TOKENS, 16, "test geometry assumes 16-token pages");
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 60);
    let model = latent.build_float(8, 64); // 32-page pool: no pressure

    // Donor run: full prefill, then the first sampled token registers
    // the 24-token prompt as a pin holding ceil(24/16) = 2 pages. The
    // donor's own next claim copy-on-writes away from the pin's
    // partially filled tail page (cow == 1), so the pin stays frozen.
    let mut sched = Scheduler::new(&model, 1, 2);
    sched.submit(shared_requests(1, 20, 24, 6).pop().unwrap());
    let done = sched.run();
    assert_eq!(done.len(), 1);
    assert_eq!(sched.stats().prefix_hits, 0, "donor must be a miss");
    assert_eq!(model.kv_prefix_pins(), 1);
    assert_eq!(model.kv_pages_in_use(), 24usize.div_ceil(KV_PAGE_TOKENS),
               "after the donor retires only the pin holds pages");
    assert_eq!(model.kv_cow_copies(), 1,
               "the donor CoWs off the pin's tail page exactly once");
    assert_eq!(model.kv_live_seqs(), 1, "the pin is the only live seq");

    // Four followers, admitted together: each maps 20 shared tokens
    // (boundary 16 verified, tail-extended to the divergence point at
    // 20) and CoWs one private tail page on its first claim.
    let mut sched = Scheduler::new(&model, 4, 2);
    for r in shared_requests(5, 20, 24, 6).into_iter().skip(1) {
        sched.submit(r);
    }
    let mut done = sched.step();
    // Physically: 2 pin pages (page 0 shared five ways, counted once)
    // + 4 private CoW tails = 6. Unshared serving would need 2 + 4*2
    // = 10 pages for the same lanes.
    assert_eq!(model.kv_pages_in_use(), 6,
               "shared prefix pages must be counted once across lanes");
    while sched.pending() > 0 {
        sched.step_into(&mut done);
    }
    assert_eq!(done.len(), 4);
    assert_eq!(sched.stats().prefix_hits, 4);
    assert_eq!(sched.stats().prefix_tokens_reused, 4 * 20);
    assert_eq!(sched.stats().requeued, 0);
    assert_eq!(model.kv_cow_copies(), 5, "one CoW per diverging lane");
    assert_eq!(model.kv_pages_in_use(), 2,
               "follower retirement must free every non-pin page");

    // Refcounted release: dropping the pin returns the last holders'
    // pages to the free list; a second release has nothing to drop.
    assert!(model.release_cached_pages());
    assert_eq!(model.kv_prefix_pins(), 0);
    assert_eq!(model.kv_pages_in_use(), 0, "no page may leak");
    assert_eq!(model.kv_live_seqs(), 0);
    assert!(!model.release_cached_pages());
}

/// Acceptance (b): a prefix-hit lane's post-divergence stream is
/// bitwise identical to an unshared decode — for FloatLM, QuantLM-RTN,
/// QuantLM-GPTQ and TriLM storage. The unshared reference is a manual
/// one-lane `step_batch` loop on a second model instance (the legacy
/// path never consults the prefix cache).
#[test]
fn prefix_hit_streams_match_unshared_decode_in_every_family() {
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 61);
    let specs = [
        FamilySpec::Float,
        FamilySpec::Quant { bits: 3, group: 128, method: QuantMethod::Rtn },
        FamilySpec::Quant { bits: 4, group: 128, method: QuantMethod::Gptq },
        FamilySpec::Ternary,
    ];
    let requests = shared_requests(4, 20, 24, 6);
    for spec in specs {
        let shared_model = latent.build(spec, 4, 32).unwrap();
        let manual_model = latent.build(spec, 4, 32).unwrap();
        // Unshared reference: full prefill for every request.
        let mut reference: Vec<Vec<u32>> = Vec::new();
        for req in &requests {
            let mut state = vec![0.0f32; dims().hidden];
            let mut toks = Vec::new();
            let mut next = req.prompt[0];
            let mut pos = 1usize;
            while toks.len() < req.max_new_tokens {
                let mut refs = [state.as_mut_slice()];
                let logits = manual_model.step_batch(&mut refs, &[next], 2);
                if pos < req.prompt.len() {
                    next = req.prompt[pos];
                    pos += 1;
                } else {
                    let row = logits.row(0);
                    let mut best = 0usize;
                    for (i, &v) in row.iter().enumerate() {
                        if v > row[best] {
                            best = i;
                        }
                    }
                    toks.push(best as u32);
                    next = best as u32;
                }
            }
            reference.push(toks);
        }
        // Shared run: sequential lanes so the donor's pin exists before
        // any follower is admitted — every follower must hit.
        let mut sched = Scheduler::new(shared_model.as_ref(), 1, 2);
        for r in requests.clone() {
            sched.submit(r);
        }
        let done = sched.run();
        assert_eq!(sched.stats().prefix_hits, 3,
                   "{}: every follower must reuse the pinned prefix",
                   spec.label());
        assert_eq!(sched.stats().prefix_tokens_reused, 3 * 20,
                   "{}", spec.label());
        for (c, want) in done.iter().zip(reference.iter()) {
            assert_eq!(&c.tokens, want,
                       "{}: request {} diverged from the unshared \
                        reference after a prefix hit", spec.label(), c.id);
        }
    }
}

/// Acceptance (c): copy-on-write isolation at the cache layer, both
/// directions — a sibling's post-share writes never reach the source's
/// slots, and the source's later growth never reaches the sibling.
/// Small geometry (4-token pages) so the shared partial tail page is
/// easy to point at.
#[test]
fn cow_keeps_sibling_reads_intact_both_directions() {
    let mut cache = KvCache::for_lanes(2, 4, 4, 4, 16);
    let src = cache.alloc_seq();
    cache.begin_tokens(src, 6).unwrap();
    let stamp = |tag: f32, layer: usize, pos: usize| -> Vec<f32> {
        (0..4).map(|i| tag + (layer * 100 + pos * 10 + i) as f32).collect()
    };
    for pos in 0..6 {
        for layer in 0..2 {
            cache.write_kv_at(src, layer, pos,
                              &stamp(1000.0, layer, pos),
                              &stamp(2000.0, layer, pos));
        }
    }
    let dst = cache.alloc_seq();
    assert_eq!(cache.share_prefix(src, dst, 6), 2);
    assert_eq!(cache.page_refcount(src, 0), 2);
    assert_eq!(cache.page_refcount(src, 5), 2, "partial tail is shared");

    // Sibling diverges: the claim CoWs the partial tail, the write
    // lands in the private copy only.
    cache.begin_tokens(dst, 1).unwrap();
    assert_eq!(cache.cow_copies(), 1);
    for layer in 0..2 {
        cache.write_kv_at(dst, layer, 6,
                          &stamp(5000.0, layer, 6), &stamp(6000.0, layer, 6));
    }
    assert_eq!(cache.page_refcount(src, 5), 1, "src owns its tail again");
    assert_eq!(cache.page_refcount(dst, 5), 1);
    assert_eq!(cache.page_refcount(src, 0), 2, "full page stays shared");
    for pos in 0..6 {
        for layer in 0..2 {
            let (k, v) = cache.kv(src, layer, pos);
            assert_eq!(k, &stamp(1000.0, layer, pos)[..],
                       "src k corrupted at layer {layer} pos {pos}");
            assert_eq!(v, &stamp(2000.0, layer, pos)[..]);
            let (dk, dv) = cache.kv(dst, layer, pos);
            assert_eq!(dk, k, "shared slots must read identically");
            assert_eq!(dv, v);
        }
    }

    // Source grows past the (formerly shared) tail: no CoW needed now
    // (it is the sole holder again), and the sibling's view of the
    // committed prefix is untouched.
    cache.begin_tokens(src, 1).unwrap();
    assert_eq!(cache.cow_copies(), 1, "exclusive tail needs no copy");
    for layer in 0..2 {
        cache.write_kv_at(src, layer, 6,
                          &stamp(7000.0, layer, 6), &stamp(8000.0, layer, 6));
    }
    for layer in 0..2 {
        let (dk, dv) = cache.kv(dst, layer, 6);
        assert_eq!(dk, &stamp(5000.0, layer, 6)[..],
                   "src growth leaked into the sibling's copy");
        assert_eq!(dv, &stamp(6000.0, layer, 6)[..]);
    }

    // Refcounted free: retiring the source keeps the shared full page
    // alive for the sibling; retiring the sibling returns everything.
    let before = cache.free_page_count();
    cache.free_seq(src);
    assert_eq!(cache.free_page_count(), before + 1,
               "only src's exclusive tail page may return to the free \
                list; the shared full page still has a holder");
    let (dk, _) = cache.kv(dst, 0, 0);
    assert_eq!(dk, &stamp(1000.0, 0, 0)[..],
               "freeing the source invalidated the sibling's prefix");
    cache.free_seq(dst);
    assert_eq!(cache.pages_in_use(), 0, "no page may leak after churn");
}

/// Acceptance (d): page-churn soak — shared traffic through a pool
/// tight enough to force requeues (and possibly pin evictions), on one
/// long-lived model across two scheduler lifetimes. Streams stay
/// bitwise identical to a roomy run, and the only pages still held at
/// the end belong to surviving pins, all reclaimed by one release.
#[test]
fn churn_and_requeue_leak_no_pages_and_keep_streams() {
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 62);
    let roomy = latent.build_float(8, 64);
    let mut sched = Scheduler::new(&roomy, 1, 2);
    for r in shared_requests(8, 20, 24, 6) {
        sched.submit(r);
    }
    let reference: Vec<Vec<u32>> =
        sched.run().into_iter().map(|c| c.tokens).collect();

    let tight = latent.build_float(3, 24); // 6 pages for 4-lane traffic
    let mut requeued_total = 0usize;
    for _round in 0..2 {
        let mut sched = Scheduler::new(&tight, 4, 2);
        for r in shared_requests(8, 20, 24, 6) {
            sched.submit(r);
        }
        let got: Vec<Vec<u32>> =
            sched.run().into_iter().map(|c| c.tokens).collect();
        assert_eq!(got, reference,
                   "requeue/eviction churn must never change streams");
        requeued_total += sched.stats().requeued;
        // Between rounds (and after the last): only pins hold pages.
        assert_eq!(tight.kv_pages_in_use(),
                   tight.kv_prefix_pins() * 24usize.div_ceil(KV_PAGE_TOKENS),
                   "a retired fleet may leave behind pin pages only");
    }
    assert!(requeued_total > 0,
            "geometry failed to exercise KV backpressure requeues");
    // Eviction is one pin per release call (LRU first), so drain
    // whatever survived the churn pin by pin.
    while tight.kv_prefix_pins() > 0 {
        assert!(tight.release_cached_pages());
    }
    assert_eq!(tight.kv_pages_in_use(), 0, "no page may leak");
    assert_eq!(tight.kv_live_seqs(), 0);
}

/// Eviction-policy regression: releasing cached pages drops *one* pin
/// at a time, least-recently-hit first — not the old all-or-nothing
/// valve that emptied the cache on any backpressure step. Two pins,
/// with the older one refreshed by a lookup hit: the first release
/// must evict only the stale pin, the refreshed pin must keep serving
/// hits, and repeated releases drain the cache pin by pin.
#[test]
fn eviction_drops_one_least_recently_hit_pin_at_a_time() {
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 65);
    let model = latent.build_float(8, 64); // roomy: pins never contend

    let prompt = |salt: u32| -> Vec<u32> {
        (0..24u32).map(|j| (salt + 3 * j + 11) % 128).collect()
    };
    // Pin A (salt 0) then pin B (salt 64): insertion order is the
    // initial recency order.
    for (id, salt) in [(0usize, 0u32), (1, 64)] {
        let mut sched = Scheduler::new(&model, 1, 2);
        sched.submit(GenRequest::greedy(id, prompt(salt), 6));
        sched.run();
    }
    assert_eq!(model.kv_prefix_pins(), 2);

    // Refresh A: a lookup hit stamps its pin most-recently-used, so B
    // — registered later but never hit — is now the LRU entry.
    let mut sched = Scheduler::new(&model, 1, 2);
    sched.submit(GenRequest::greedy(2, prompt(0), 6));
    sched.run();
    assert_eq!(sched.stats().prefix_hits, 1, "A must still be pinned");

    // One release evicts exactly one pin — the stale B, not the
    // recently hit A.
    assert!(model.release_cached_pages());
    assert_eq!(model.kv_prefix_pins(), 1,
               "eviction must drop one pin, not the whole cache");
    let mut sched = Scheduler::new(&model, 1, 2);
    sched.submit(GenRequest::greedy(3, prompt(0), 6));
    sched.run();
    assert_eq!(sched.stats().prefix_hits, 1,
               "the most recently hit pin must survive the eviction");
    let mut sched = Scheduler::new(&model, 1, 2);
    sched.submit(GenRequest::greedy(4, prompt(64), 6));
    sched.run();
    assert_eq!(sched.stats().prefix_hits, 0,
               "the least recently hit pin must be the one evicted");

    // That miss re-registered B; drain the cache one pin per call.
    assert_eq!(model.kv_prefix_pins(), 2);
    assert!(model.release_cached_pages());
    assert!(model.release_cached_pages());
    assert!(!model.release_cached_pages(), "nothing left to evict");
    assert_eq!(model.kv_prefix_pins(), 0);
    assert_eq!(model.kv_pages_in_use(), 0, "no page may leak");
    assert_eq!(model.kv_live_seqs(), 0);
}

/// Acceptance (e): the correctness heart. A sole live lane refused its
/// claim because *pinned* pages fill the pool is a recoverable state:
/// the scheduler must evict the pins before requeueing the lane —
/// never trip the "cache smaller than a single request" sizing panic
/// (pre-eviction behavior) — and the restarted lane's stream must be
/// bitwise identical to an uncontended run.
#[test]
fn pinned_pages_under_backpressure_evict_instead_of_panicking() {
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 63);
    let model = latent.build_float(2, 32); // 4-page pool

    // Donor leaves a 2-page pin behind (24-token prompt, free pool has
    // slack for its own CoW).
    let mut sched = Scheduler::new(&model, 1, 2);
    sched.submit(shared_requests(1, 20, 24, 6).pop().unwrap());
    sched.run();
    assert_eq!(model.kv_prefix_pins(), 1);
    assert_eq!(model.kv_pages_in_use(), 2);

    // An unrelated long request (36 tokens = 3 pages) misses the cache
    // and needs more pages than the 2 the pin left free. Its third
    // page claim is refused with every other lane idle — exactly the
    // sizing-panic trigger — but the pinned pages are evictable, so
    // the step must instead release them, requeue the lane once, and
    // complete.
    let long = GenRequest::greedy(
        99, (0..24u32).map(|j| (13 * j + 5) % 128).collect(), 12);
    let uncontended = {
        let roomy = latent.build_float(8, 64);
        let mut sched = Scheduler::new(&roomy, 1, 2);
        sched.submit(long.clone());
        sched.run().pop().unwrap().tokens
    };
    let mut sched = Scheduler::new(&model, 1, 2);
    sched.submit(long);
    let done = sched.run();
    assert_eq!(done.len(), 1, "the refused lane must complete");
    assert_eq!(done[0].tokens, uncontended,
               "evict-then-requeue must reproduce the uncontended stream");
    assert_eq!(sched.stats().requeued, 1,
               "the lane restarts exactly once after the eviction");
    assert_eq!(sched.stats().prefix_hits, 0, "unrelated prompt: a miss");
    assert_eq!(model.kv_prefix_pins(), 0, "the pin was evicted");
    assert_eq!(model.kv_pages_in_use(), 0);
}

/// Livelock regression for the eviction relief valve: when a prompt's
/// prefill fills the *entire* pool, registering a pin would make the
/// donor's very next claim bounce off its own pin — evict, requeue,
/// re-register, forever (eviction counts as progress, so the stall
/// guard never fires). `prefix_register` must skip pinning on a full
/// pool; the request completes with no pin, no requeue.
#[test]
fn full_pool_skips_pinning_instead_of_looping() {
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 64);
    let model = latent.build_float(1, 32); // 2 pages == the prompt
    let mut sched = Scheduler::new(&model, 1, 2);
    sched.submit(shared_requests(1, 20, 24, 6).pop().unwrap());
    let done = sched.run();
    assert_eq!(done.len(), 1);
    assert_eq!(sched.stats().requeued, 0,
               "a zero-slack donor must run straight through");
    assert_eq!(model.kv_prefix_pins(), 0,
               "a full pool must never grow the prefix cache");
    assert_eq!(model.kv_cow_copies(), 0);
    assert_eq!(model.kv_pages_in_use(), 0);
}
