//! Loopback acceptance harness for the HTTP serving front end
//! (`spectra::server`), over real sockets:
//!
//! 1. **Bitwise streaming** — for all four storage families (FloatLM,
//!    QuantLM-RTN, QuantLM-GPTQ, TriLM), the token sequence streamed
//!    over `POST /generate` chunked ndjson is bitwise equal to the
//!    same request run through a [`Scheduler`] directly on an
//!    identically-built model. The HTTP layer is transport, never
//!    semantics.
//! 2. **Backpressure as protocol** — a full admission queue answers
//!    `429` with a `Retry-After` header (and never panics the
//!    scheduler); an over-context request answers `413` *before*
//!    touching the KV pool.
//! 3. **Stats consistency** — `/stats` reports queue-depth, rejection,
//!    and per-tenant counters that add up against what the harness
//!    actually did, and agrees with the [`ShardSnapshot`]s the server
//!    hands back at shutdown.
//! 4. **Graceful drain** — shutdown completes every admitted stream
//!    (parked ones included) and releases every KV page — the same
//!    zero-leak bar `tests/prefix_sharing.rs` holds the cache to.
//!
//! [`ShardSnapshot`]: spectra::server::ShardSnapshot

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use spectra::serve::{DecodeModel, FamilySpec, GenRequest, LatentAttnLm,
                     LmDims, QuantMethod, Sampling, Scheduler};
use spectra::server::{http, Server, ServerConfig};
use spectra::util::json::Json;

fn dims() -> LmDims {
    LmDims { vocab: 128, hidden: 64, glu: 96, layers: 3 }
}

/// The four serving families of the acceptance bar (same set as
/// `tests/serve_determinism.rs`; group 128 at these dims exercises the
/// ragged-group path, and GPTQ exercises the calibration-seeded
/// builder).
fn four_families() -> [FamilySpec; 4] {
    [
        FamilySpec::Float,
        FamilySpec::Quant { bits: 3, group: 128, method: QuantMethod::Rtn },
        FamilySpec::Quant { bits: 4, group: 128, method: QuantMethod::Gptq },
        FamilySpec::Ternary,
    ]
}

fn config(family: FamilySpec) -> ServerConfig {
    ServerConfig {
        port: 0,
        shards: 2,
        lanes: 2,
        threads: 1,
        prefill_chunk: 4,
        queue_cap: 4,
        kv_context: 64,
        family,
        attn: true,
        heads: 4,
        dims: dims(),
        mp: 1,
        seed: 77,
        ..ServerConfig::default()
    }
}

/// Mirror of the server's per-shard model construction (the concrete
/// builders, with `cfg.seed` as the GPTQ calibration seed — the
/// generic [`LatentAttnLm::build`] calibrates with seed 0, which would
/// be a *different* GPTQ model). Same latent seed → bitwise-identical
/// weights, so this box decodes exactly what every shard decodes.
fn build_reference(cfg: &ServerConfig) -> Box<dyn DecodeModel> {
    let latent = LatentAttnLm::synthetic(cfg.dims.clone(), cfg.heads,
                                         cfg.mp, cfg.seed);
    match cfg.family {
        FamilySpec::Float =>
            Box::new(latent.build_float(cfg.lanes, cfg.kv_context)),
        FamilySpec::Ternary =>
            Box::new(latent.build_ternary(cfg.lanes, cfg.kv_context)),
        FamilySpec::Quant { bits, group, method: QuantMethod::Rtn } =>
            Box::new(latent.build_quant_rtn(bits, group, cfg.lanes,
                                            cfg.kv_context)),
        FamilySpec::Quant { bits, group, method: QuantMethod::Gptq } =>
            Box::new(latent.build_quant_gptq(bits, group, cfg.seed,
                                             cfg.lanes, cfg.kv_context)
                     .expect("gptq build")),
    }
}

/// Parse a complete ndjson stream body into (tokens, done-trailer),
/// asserting in-order indices and a token-count-consistent trailer.
fn parse_stream(body: &str) -> (Vec<u32>, Json) {
    let mut tokens = Vec::new();
    let mut done = None;
    for line in body.lines() {
        let doc = Json::parse(line).expect("every stream line is JSON");
        if doc.opt("done").is_some() {
            assert!(done.is_none(), "exactly one done trailer");
            done = Some(doc);
        } else {
            assert!(done.is_none(), "no token lines after the trailer");
            assert_eq!(doc.get("index").unwrap().as_usize().unwrap(),
                       tokens.len(),
                       "token lines arrive in order, each index once");
            tokens.push(doc.get("token").unwrap().as_usize().unwrap() as u32);
        }
    }
    let done = done.expect("stream must end with a done trailer");
    assert_eq!(done.get("tokens").unwrap().as_usize().unwrap(), tokens.len());
    assert!(done.get("finish_reason").unwrap().as_str().is_ok(),
            "the done trailer must say why the stream ended");
    (tokens, done)
}

fn get_stats(addr: &SocketAddr) -> Json {
    let resp = http::client_roundtrip(addr, "GET", "/stats", b"").unwrap();
    assert_eq!(resp.status, 200);
    Json::parse(&resp.body_str()).expect("/stats must be parseable JSON")
}

#[test]
fn streams_are_bitwise_equal_to_direct_scheduler_for_all_families() {
    for family in four_families() {
        let cfg = config(family);
        let server = Server::start(cfg.clone()).unwrap();
        let addr = server.addr();

        // Mixed traffic: greedy and seeded top-k, two tenants, prompts
        // that spread over both shards' prefix-hash buckets.
        let prompts: Vec<Vec<u32>> =
            (0..6u32).map(|i| vec![i + 1, 2 * i + 3, 7]).collect();
        let sampling = |i: usize| -> Sampling {
            if i % 2 == 0 {
                Sampling::Greedy
            } else {
                Sampling::TopK { k: 5, temperature: 0.5,
                                 seed: 1000 + i as u64 }
            }
        };

        // Reference: identical model, driven directly one request at a
        // time — the strongest form of the claim (an HTTP stream under
        // shard routing and continuous batching equals a solo direct
        // decode; batch invariance is what makes that hold).
        let model = build_reference(&cfg);
        let reference: Vec<Vec<u32>> = prompts.iter().enumerate()
            .map(|(i, p)| {
                let mut sched = Scheduler::with_prefill_chunk(
                    &*model, 1, 1, cfg.prefill_chunk);
                sched.submit(GenRequest {
                    id: i,
                    prompt: p.clone(),
                    max_new_tokens: 5,
                    sampling: sampling(i),
                });
                sched.run().remove(0).tokens
            })
            .collect();

        for (i, p) in prompts.iter().enumerate() {
            let prompt_json: Vec<String> =
                p.iter().map(|t| t.to_string()).collect();
            let sampling_json = match sampling(i) {
                Sampling::Greedy => String::new(),
                Sampling::TopK { k, temperature, seed } => format!(
                    ",\"top_k\":{k},\"temperature\":{temperature},\
                     \"seed\":{seed}"),
            };
            let body = format!(
                "{{\"prompt\":[{}],\"max_new_tokens\":5,\
                 \"tenant\":\"{}\"{}}}",
                prompt_json.join(","),
                if i % 2 == 0 { "alpha" } else { "beta" },
                sampling_json);
            let resp = http::client_roundtrip(&addr, "POST", "/generate",
                                              body.as_bytes()).unwrap();
            assert_eq!(resp.status, 200, "family {family:?} request {i}");
            assert!(resp.header("transfer-encoding")
                    .is_some_and(|v| v.contains("chunked")),
                    "token streams must use chunked transfer encoding");
            let (tokens, done) = parse_stream(&resp.body_str());
            assert_eq!(tokens.len(), 5);
            assert_eq!(tokens, reference[i],
                       "family {family:?} request {i}: HTTP stream must \
                        be bitwise-equal to direct scheduler output");
            assert_eq!(done.get("prompt_len").unwrap().as_usize().unwrap(),
                       p.len());
        }

        // Tenant counters survived the traffic.
        let doc = get_stats(&addr);
        assert_eq!(doc.get("served").unwrap().as_usize().unwrap(), 6);
        assert_eq!(doc.get("rejected_429").unwrap().as_usize().unwrap(), 0);
        assert_eq!(doc.get("rejected_413").unwrap().as_usize().unwrap(), 0);
        // Robustness counters exist (schema 6) and are zero on a
        // healthy, fault-free run.
        for k in ["cancelled", "deadline_expired", "worker_restarts"] {
            assert_eq!(doc.get(k).unwrap().as_usize().unwrap(), 0,
                       "family {family:?}: {k} must be 0 without faults");
        }
        let tenants = doc.get("tenants").unwrap().as_arr().unwrap();
        let served_of = |name: &str| tenants.iter()
            .find(|t| t.get("tenant").unwrap().as_str().unwrap() == name)
            .map(|t| t.get("served").unwrap().as_usize().unwrap())
            .unwrap_or(0);
        assert_eq!(served_of("alpha"), 3);
        assert_eq!(served_of("beta"), 3);

        let finals = server.shutdown();
        assert_eq!(finals.len(), 2);
        for s in &finals {
            assert_eq!(s.kv_pages, 0,
                       "family {family:?} shard {} leaked KV pages",
                       s.shard);
        }
        assert_eq!(finals.iter().map(|s| s.served).sum::<usize>(), 6,
                   "family {family:?}: snapshots must agree with /stats");
    }
}

/// A streaming client that keeps its connection open — how the 429 and
/// drain tests pin a request inside a lane (or park one in the queue)
/// while the harness probes the server.
struct OpenStream {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl OpenStream {
    /// POST /generate and return with the connection open (nothing
    /// read) — a request that parks wherever admission puts it.
    fn connect(addr: &SocketAddr, body: &str) -> OpenStream {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        http::send_request_head(&mut stream, "POST", "/generate",
                                body.len()).unwrap();
        stream.write_all(body.as_bytes()).unwrap();
        stream.flush().unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        OpenStream { stream, reader }
    }

    /// POST /generate and block until the response head *and first
    /// token chunk* have arrived — at which point the request provably
    /// occupies a scheduler lane (only a decoding lane emits tokens).
    fn start_pinned(addr: &SocketAddr, body: &str) -> OpenStream {
        let mut s = OpenStream::connect(addr, body);
        let mut line = String::new();
        s.reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("HTTP/1.1 200"),
                "lane-pinning request must be admitted, got {line:?}");
        loop {
            line.clear();
            s.reader.read_line(&mut line).unwrap();
            if line == "\r\n" || line == "\n" {
                break; // end of head
            }
        }
        // The first chunk-size line only arrives once the worker has
        // sampled this lane's first token.
        line.clear();
        s.reader.read_line(&mut line).unwrap();
        assert!(!line.trim().is_empty(), "first chunk size line");
        s
    }

    /// Read the rest of the stream to EOF (drains the connection so
    /// the server's handler finishes cleanly).
    fn finish(mut self) {
        let mut rest = Vec::new();
        let _ = self.reader.read_to_end(&mut rest);
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

/// Poll `/stats` until the admission queue holds `want` request(s) —
/// the deterministic "the parked request is enqueued" barrier the 429
/// probe fires behind.
fn wait_for_queue_depth(addr: &SocketAddr, want: usize) {
    for _ in 0..1000 {
        let doc = get_stats(addr);
        if doc.get("queue_depth").unwrap().as_usize().unwrap() >= want {
            return;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    panic!("admission queue never reached depth {want}");
}

#[test]
fn full_queue_answers_429_with_retry_after_and_oversize_413() {
    // One shard, one lane, queue cap 1: exact admission arithmetic.
    // The pinned request decodes 1500 tokens, so the lane stays busy
    // for far longer than the milliseconds the probes below need.
    let cfg = ServerConfig {
        shards: 1,
        lanes: 1,
        queue_cap: 1,
        kv_context: 1600,
        ..config(FamilySpec::Float)
    };
    let server = Server::start(cfg).unwrap();
    let addr = server.addr();

    // 413 first: 3 + 5000 > 1600, refused before the KV pool is
    // touched — no panic, no page, attributed to its tenant.
    let over = http::client_roundtrip(
        &addr, "POST", "/generate",
        br#"{"prompt":[1,2,3],"max_new_tokens":5000,"tenant":"big"}"#)
        .unwrap();
    assert_eq!(over.status, 413);
    let over_doc = Json::parse(&over.body_str()).unwrap();
    assert_eq!(over_doc.get("error").unwrap().as_str().unwrap(),
               "context_too_large");

    // Pin the single lane and only proceed once its first token has
    // arrived; then park one request to fill the cap-1 queue.
    let pinned = OpenStream::start_pinned(
        &addr,
        r#"{"prompt":[5,9],"max_new_tokens":1500,"tenant":"pin"}"#);
    let parked = OpenStream::connect(
        &addr,
        r#"{"prompt":[6,10],"max_new_tokens":1500,"tenant":"parked"}"#);
    wait_for_queue_depth(&addr, 1);

    // Next request must bounce: 429 + Retry-After, by protocol.
    let full = http::client_roundtrip(
        &addr, "POST", "/generate",
        br#"{"prompt":[7,11],"max_new_tokens":4,"tenant":"bounced"}"#)
        .unwrap();
    assert_eq!(full.status, 429);
    assert!(full.header("retry-after").is_some(),
            "429 must carry Retry-After");
    let full_doc = Json::parse(&full.body_str()).unwrap();
    assert_eq!(full_doc.get("error").unwrap().as_str().unwrap(),
               "queue_full");

    // /stats while the queue is full: depth 1, max 1, one 429, one
    // 413, each attributed to the right tenant.
    let doc = get_stats(&addr);
    assert_eq!(doc.get("queue_depth").unwrap().as_usize().unwrap(), 1);
    assert_eq!(doc.get("queue_depth_max").unwrap().as_usize().unwrap(), 1);
    assert_eq!(doc.get("rejected_429").unwrap().as_usize().unwrap(), 1);
    assert_eq!(doc.get("rejected_413").unwrap().as_usize().unwrap(), 1);
    let tenants = doc.get("tenants").unwrap().as_arr().unwrap();
    let tenant = |name: &str| tenants.iter().find(
        |t| t.get("tenant").unwrap().as_str().unwrap() == name)
        .unwrap_or_else(|| panic!("tenant {name} missing from /stats"));
    assert_eq!(tenant("bounced").get("rejected").unwrap()
               .as_usize().unwrap(), 1);
    assert_eq!(tenant("big").get("rejected").unwrap()
               .as_usize().unwrap(), 1);
    assert_eq!(tenant("parked").get("queued").unwrap()
               .as_usize().unwrap(), 1);

    // Both live streams complete (the parked one runs after the pin
    // finishes), then a graceful drain leaks nothing. The snapshot's
    // embedded ServeStats carries the same schema-5 counters — one
    // story told in two places.
    pinned.finish();
    parked.finish();
    let finals = server.shutdown();
    assert_eq!(finals.len(), 1);
    assert_eq!(finals[0].kv_pages, 0, "shard leaked KV pages");
    assert_eq!(finals[0].served, 2, "pinned + parked both served");
    assert_eq!(finals[0].rejected_429, 1);
    assert_eq!(finals[0].rejected_413, 1);
    assert_eq!(finals[0].sched.rejected_429, 1);
    assert_eq!(finals[0].sched.rejected_413, 1);
    assert_eq!(finals[0].sched.queue_depth_max, 1);
}

#[test]
fn graceful_shutdown_drains_parked_requests() {
    let cfg = ServerConfig {
        shards: 1,
        lanes: 1,
        queue_cap: 2,
        kv_context: 700,
        ..config(FamilySpec::Ternary)
    };
    let server = Server::start(cfg).unwrap();
    let addr = server.addr();

    // One request live in the lane, one parked in the queue when the
    // drain begins. Both must be served to completion — a drain that
    // dropped parked work would close their streams without trailers
    // and leave served at 1.
    let pinned = OpenStream::start_pinned(
        &addr, r#"{"prompt":[5,9],"max_new_tokens":600,"tenant":"a"}"#);
    let parked = OpenStream::connect(
        &addr, r#"{"prompt":[6,10],"max_new_tokens":3,"tenant":"b"}"#);
    wait_for_queue_depth(&addr, 1);

    let finals = server.shutdown();
    assert_eq!(finals[0].served, 2,
               "drain must complete parked requests, not drop them");
    assert_eq!(finals[0].kv_pages, 0, "drain must release every KV page");
    pinned.finish();
    parked.finish();
}
