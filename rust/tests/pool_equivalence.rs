//! Pooled-execution equivalence harness: the persistent-[`WorkerPool`]
//! serving path against the scoped-thread reference, bitwise.
//!
//! The pool removes per-matmul thread spawns and per-step allocations
//! from the decode hot path; this suite pins down that it removes
//! *only* overhead, never numerics:
//!
//! - pooled vs scoped blocked kernels (ternary and k-bit quant) are
//!   bitwise identical over the kernel-equivalence shape grid, at
//!   every tested batch size and thread count (including the
//!   threads=1 inline fallback);
//! - one pool + one scratch reused across many calls of many shapes
//!   produces the same results as fresh per-call execution (stale
//!   scratch can never leak);
//! - the pooled dense path is bitwise identical to `matmul_dense`;
//! - the paged KV-cache attention model's scratch-aware step is
//!   bitwise identical to its allocating scoped-thread step, for every
//!   storage family.

use spectra::linear::{matmul_quant_packed, matmul_quant_packed_into,
                      DenseF32, LinearFormat, QuantPacked};
use spectra::quant::QuantTensor;
use spectra::runtime::{DecodeScratch, HostTensor, WorkerPool};
use spectra::serve::{DecodeModel, FamilySpec, LatentAttnLm, LmDims,
                     QuantMethod};
use spectra::ternary::matmul::{COL_BLOCK_TRITS, ROW_BLOCK};
use spectra::ternary::{matmul_dense, matmul_ternary_packed,
                       matmul_ternary_packed_into, PackedMatrix,
                       TernaryTensor};

/// The kernel-equivalence shape grid (edge + tile-spanning shapes).
fn shape_grid() -> Vec<(usize, usize)> {
    vec![
        (1, 4),
        (1, 7),
        (3, 5),
        (7, 10),
        (16, 16),
        (33, 64),
        (ROW_BLOCK + 9, COL_BLOCK_TRITS + 37),
        (64, 48),
    ]
}

#[test]
fn pooled_ternary_matches_scoped_bitwise_over_grid() {
    let mut seed = 0x900Du64;
    let mut out_t = Vec::new();
    let mut out = HostTensor::zeros(vec![0, 0]);
    for threads in [1usize, 2, 5] {
        let pool = WorkerPool::new(threads);
        assert_eq!(pool.threads(), threads);
        for (rows, cols) in shape_grid() {
            seed += 1;
            let w = HostTensor::randn(vec![rows, cols], 0.05, seed);
            let t = TernaryTensor::from_latent(&w, 1);
            let pm = PackedMatrix::from_ternary(&t);
            for m in [1usize, 3, 8] {
                let x = HostTensor::randn(vec![m, cols], 1.0,
                                          seed ^ (m as u64) << 8);
                let want = matmul_ternary_packed(&x, &pm, threads);
                matmul_ternary_packed_into(&x, &pm, &pool, &mut out_t,
                                           &mut out);
                assert_eq!(out.shape, want.shape,
                           "{rows}x{cols} m{m} t{threads}");
                assert_eq!(out.data, want.data,
                           "{rows}x{cols} m{m} t{threads}: pooled ternary \
                            diverges from scoped");
            }
        }
    }
}

#[test]
fn pooled_quant_matches_scoped_bitwise_over_grid() {
    let mut seed = 0x900Eu64;
    let mut out_t = Vec::new();
    let mut out = HostTensor::zeros(vec![0, 0]);
    for bits in [3u32, 4] {
        for threads in [1usize, 2, 5] {
            let pool = WorkerPool::new(threads);
            for (rows, cols) in [(1usize, 7usize), (8, 100), (33, 130),
                                 (ROW_BLOCK + 9, COL_BLOCK_TRITS + 37)] {
                seed += 1;
                let w = HostTensor::randn(vec![rows, cols], 0.05, seed);
                let qp = QuantPacked::from_quant(
                    &QuantTensor::quantize_rtn(&w, bits, 128));
                for m in [1usize, 8] {
                    let x = HostTensor::randn(vec![m, cols], 1.0,
                                              seed ^ (m as u64) << 8);
                    let want = matmul_quant_packed(&x, &qp, threads);
                    matmul_quant_packed_into(&x, &qp, &pool, &mut out_t,
                                             &mut out);
                    assert_eq!(out.data, want.data,
                               "{rows}x{cols} b{bits} m{m} t{threads}: \
                                pooled quant diverges from scoped");
                }
            }
        }
    }
}

#[test]
fn pooled_dense_matches_matmul_dense_bitwise() {
    let pool = WorkerPool::new(3);
    let mut out_t = Vec::new();
    let mut out = HostTensor::zeros(vec![0, 0]);
    for (rows, cols) in [(16usize, 16usize), (ROW_BLOCK + 9, 70)] {
        let d = DenseF32 { w: HostTensor::randn(vec![rows, cols], 0.1, 51) };
        for m in [1usize, 8] {
            let x = HostTensor::randn(vec![m, cols], 1.0, 52 + m as u64);
            let want = matmul_dense(&x, &d.w);
            d.matmul_batch_into(&x, &pool, &mut out_t, &mut out);
            assert_eq!(out.data, want.data, "{rows}x{cols} m{m}");
        }
    }
}

#[test]
fn one_pool_and_scratch_survive_many_mixed_calls() {
    // The serving pattern: one pool + one scratch, thousands of
    // dispatches over shapes that shrink and grow between calls. Every
    // result must match per-call scoped execution — stale out_t/out
    // contents and stale thread-local panels must never leak.
    let pool = WorkerPool::new(4);
    let mut out_t = Vec::new();
    let mut out = HostTensor::zeros(vec![0, 0]);
    let shapes = [(40usize, 24usize), (7, 10), (ROW_BLOCK + 1, 64),
                  (3, COL_BLOCK_TRITS + 5), (40, 24)];
    for round in 0..30 {
        let (rows, cols) = shapes[round % shapes.len()];
        let w = HostTensor::randn(vec![rows, cols], 0.05, 60 + round as u64);
        let t = TernaryTensor::from_latent(&w, 1);
        let pm = PackedMatrix::from_ternary(&t);
        let m = 1 + round % 8;
        let x = HostTensor::randn(vec![m, cols], 1.0, 90 + round as u64);
        let want = matmul_ternary_packed(&x, &pm, 4);
        matmul_ternary_packed_into(&x, &pm, &pool, &mut out_t, &mut out);
        assert_eq!(out.data, want.data, "round {round} {rows}x{cols} m{m}");
    }
}

#[test]
fn attn_pooled_step_matches_scoped_step_bitwise() {
    // The attention decode path rides the same pooled drivers as the
    // gated MLP; its scratch-aware step must be bitwise identical to
    // the allocating scoped-thread step — logits and state tags — for
    // every storage family, with ONE scratch reused across families,
    // shapes, and thread counts. Two instances per family: the paged
    // KV cache is stateful, so one instance cannot run both paths.
    let dims = LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 };
    let latent = LatentAttnLm::synthetic(dims, 4, 1, 0x477);
    let mut scratch = DecodeScratch::new();
    let specs = [
        FamilySpec::Float,
        FamilySpec::Quant { bits: 3, group: 128, method: QuantMethod::Rtn },
        FamilySpec::Ternary,
    ];
    for threads in [1usize, 2, 4] {
        let pool = WorkerPool::new(threads);
        for spec in specs {
            let m_a = latent.build(spec, 3, 12).unwrap();
            let m_b = latent.build(spec, 3, 12).unwrap();
            let mut st_a = vec![vec![0.0f32; 32]; 3];
            let mut st_b = st_a.clone();
            for (step, toks) in [[5u32, 9, 60], [4, 4, 31], [7, 0, 2]]
                .iter().enumerate()
            {
                let mut refs_a: Vec<&mut [f32]> =
                    st_a.iter_mut().map(|s| s.as_mut_slice()).collect();
                let want = m_a.step_batch(&mut refs_a, toks, threads);
                let mut refs_b: Vec<&mut [f32]> =
                    st_b.iter_mut().map(|s| s.as_mut_slice()).collect();
                m_b.step_batch_into(&mut refs_b, toks, &pool, &mut scratch);
                assert_eq!(scratch.logits.shape, want.shape,
                           "{} t{threads} step {step}", spec.label());
                assert_eq!(scratch.logits.data, want.data,
                           "{} t{threads} step {step}: attn pooled step \
                            diverges from scoped", spec.label());
                assert_eq!(st_a, st_b, "{} t{threads} step {step}: states",
                           spec.label());
            }
        }
    }
}

#[test]
fn single_thread_pool_is_the_inline_fallback() {
    // threads = 1 must mean: no workers, no dispatch, results bitwise
    // equal to the single-threaded scoped path.
    let pool = WorkerPool::new(1);
    let w = HostTensor::randn(vec![48, COL_BLOCK_TRITS + 11], 0.05, 71);
    let t = TernaryTensor::from_latent(&w, 2);
    let pm = PackedMatrix::from_ternary(&t);
    let x = HostTensor::randn(vec![8, t.cols], 1.0, 72);
    let want = matmul_ternary_packed(&x, &pm, 1);
    let mut out_t = Vec::new();
    let mut out = HostTensor::zeros(vec![0, 0]);
    matmul_ternary_packed_into(&x, &pm, &pool, &mut out_t, &mut out);
    assert_eq!(out.data, want.data);
}

#[test]
fn pooled_results_are_thread_count_invariant() {
    // The serve determinism contract, stated directly on the pooled
    // kernels: the thread count only partitions rows, it never
    // reorders accumulation.
    let w = HostTensor::randn(vec![96, COL_BLOCK_TRITS + 19], 0.05, 81);
    let t = TernaryTensor::from_latent(&w, 2);
    let pm = PackedMatrix::from_ternary(&t);
    let x = HostTensor::randn(vec![8, t.cols], 1.0, 82);
    let mut out_t = Vec::new();
    let mut reference = HostTensor::zeros(vec![0, 0]);
    matmul_ternary_packed_into(&x, &pm, &WorkerPool::new(1), &mut out_t,
                               &mut reference);
    for threads in [2usize, 3, 8] {
        let pool = WorkerPool::new(threads);
        let mut got = HostTensor::zeros(vec![0, 0]);
        matmul_ternary_packed_into(&x, &pm, &pool, &mut out_t, &mut got);
        assert_eq!(got.data, reference.data, "threads={threads}");
    }
}
