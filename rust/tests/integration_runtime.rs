//! Integration tests over the real PJRT runtime + AOT artifacts:
//! the full L3 -> L2 -> L1 composition. Tests skip (pass trivially)
//! when `artifacts/` is absent so `cargo test` works pre-`make artifacts`.

use spectra::config::{Family, TrainConfig};
use spectra::coordinator::Trainer;
use spectra::data::{Batcher, Dataset};
use spectra::eval::{self, Evaluator, TaskKind};
use spectra::runtime::{self, Runtime};
use spectra::ternary::TernaryTensor;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

fn dataset() -> Dataset {
    Dataset::build(std::path::Path::new("runs/data_test"), 300_000, 7)
        .expect("dataset")
}

#[test]
fn train_step_runs_and_initial_loss_is_uniform() {
    let Some(rt) = runtime() else { return };
    let data = dataset();
    let cfg = TrainConfig::for_family(Family::Ternary, 100);
    let mut trainer = Trainer::new(&rt, "160k_ternary", cfg).unwrap();
    let mut batcher = Batcher::new(data.train.clone(),
                                   rt.manifest().train_batch,
                                   rt.manifest().seq, 7);
    let m = trainer.step(&batcher.next_batch()).unwrap();
    // Untrained model: CE ~= ln(512) = 6.24.
    assert!((m.loss - 512f32.ln()).abs() < 0.6, "loss {}", m.loss);
    assert!(m.grads_finite);
    assert!(m.grad_norm > 0.0);
}

#[test]
fn training_reduces_loss_for_every_family() {
    let Some(rt) = runtime() else { return };
    let data = dataset();
    for (model, family) in [("160k_float", Family::Float),
                            ("160k_ternary", Family::Ternary),
                            ("160k_binary", Family::Binary)] {
        let cfg = TrainConfig { seed: 7, ..TrainConfig::for_family(family, 40) };
        let mut trainer = Trainer::new(&rt, model, cfg).unwrap();
        let mut batcher = Batcher::new(data.train.clone(),
                                       rt.manifest().train_batch,
                                       rt.manifest().seq, 7);
        trainer.train(&mut batcher, 40, |_| {}).unwrap();
        let first = trainer.log.rows[0].loss;
        let last = trainer.log.final_loss(5);
        assert!(last < first - 0.3, "{model}: {first} -> {last}");
    }
}

#[test]
fn identical_seeds_give_identical_batches_across_families() {
    let Some(rt) = runtime() else { return };
    let data = dataset();
    // The paper's "Uniform Training" property (§4.1).
    let mut b1 = Batcher::new(data.train.clone(), rt.manifest().train_batch,
                              rt.manifest().seq, 3);
    let mut b2 = Batcher::new(data.train.clone(), rt.manifest().train_batch,
                              rt.manifest().seq, 3);
    for _ in 0..5 {
        assert_eq!(b1.next_batch(), b2.next_batch());
    }
}

#[test]
fn eval_logprobs_are_valid() {
    let Some(rt) = runtime() else { return };
    let data = dataset();
    let trainer = Trainer::new(&rt, "160k_ternary",
                               TrainConfig::for_family(Family::Ternary, 10))
        .unwrap();
    let ev = Evaluator::new(&rt, "160k_ternary").unwrap();
    let stride = rt.manifest().seq + 1;
    let block: Vec<i32> = data.train[..rt.manifest().eval_batch * stride]
        .iter().map(|&t| t as i32).collect();
    let lp = ev.logprobs(trainer.param_literals(), &block).unwrap();
    assert_eq!(lp.len(), rt.manifest().eval_batch);
    for row in &lp {
        assert_eq!(row.len(), rt.manifest().seq);
        assert!(row.iter().all(|&l| l <= 0.0 && l.is_finite()));
    }
}

#[test]
fn nll_matches_mean_of_logprobs() {
    let Some(rt) = runtime() else { return };
    let data = dataset();
    let trainer = Trainer::new(&rt, "160k_float",
                               TrainConfig::for_family(Family::Float, 10))
        .unwrap();
    let ev = Evaluator::new(&rt, "160k_float").unwrap();
    let stride = rt.manifest().seq + 1;
    let n = rt.manifest().eval_batch * stride;
    let toks: Vec<u32> = data.val[..n].to_vec();
    let nll = ev.nll(trainer.param_literals(), &toks).unwrap();
    let block: Vec<i32> = toks.iter().map(|&t| t as i32).collect();
    let lp = ev.logprobs(trainer.param_literals(), &block).unwrap();
    let manual: f64 = -lp.iter().flatten().map(|&l| l as f64).sum::<f64>()
        / (lp.len() * lp[0].len()) as f64;
    assert!((nll - manual).abs() < 1e-5, "{nll} vs {manual}");
}

#[test]
fn fp16_graph_overflows_at_huge_scale_and_skips() {
    let Some(rt) = runtime() else { return };
    let data = dataset();
    let cfg = TrainConfig { fp16: true,
                            ..TrainConfig::for_family(Family::Float, 50) };
    let mut trainer = Trainer::new(&rt, "160k_float", cfg).unwrap();
    // Force an immediate overflow: f16 max is 65504, so a scale of 2^30
    // guarantees scaled grads overflow.
    trainer.loss_scale.scale = 2f32.powi(30);
    trainer.loss_scale.min_seen = trainer.loss_scale.scale;
    let mut batcher = Batcher::new(data.train.clone(),
                                   rt.manifest().train_batch,
                                   rt.manifest().seq, 7);
    let m = trainer.step(&batcher.next_batch()).unwrap();
    assert!(!m.grads_finite, "expected overflow at scale 2^30");
    assert_eq!(trainer.loss_scale.skipped, 1);
    assert!(trainer.loss_scale.scale < 2f32.powi(30));
    // Recovery: subsequent steps at the halved scale eventually succeed.
    let mut ok = false;
    for _ in 0..25 {
        let m = trainer.step(&batcher.next_batch()).unwrap();
        if m.grads_finite {
            ok = true;
            break;
        }
    }
    assert!(ok, "loss scale never recovered");
}

#[test]
fn ternarized_deployment_matches_eval_graph_family() {
    let Some(rt) = runtime() else { return };
    // Rust-side ternarization must agree with the kernel's: ternarize a
    // trained latent matrix, dequantize, and check the values the eval
    // graph would see are reproducible (states in {-1,0,1}, per-shard
    // scales ordered like the python oracle).
    let entry = rt.manifest().model("930k_ternary").unwrap();
    let params = runtime::init_params_like(entry, 3);
    for (spec, t) in entry.params.iter().zip(params.iter()) {
        if !spec.name.contains("attn_q") {
            continue;
        }
        let tt = TernaryTensor::from_latent(t, entry.config.mp);
        assert_eq!(tt.scales.len(), entry.config.mp);
        let dq = tt.dequant();
        // dequant only contains +-gamma and 0
        for (r, row) in dq.data.chunks(tt.cols).enumerate() {
            let g = tt.row_scale(r);
            for &v in row {
                assert!(v == 0.0 || (v.abs() - g).abs() < 1e-7);
            }
        }
    }
}

#[test]
fn task_scoring_prefers_trained_answer() {
    let Some(rt) = runtime() else { return };
    let data = dataset();
    // Train briefly; the stereo task should move toward the corpus bias
    // faster than chance since it is a 2-way contrast trained densely.
    let cfg = TrainConfig { seed: 7, ..TrainConfig::for_family(Family::Ternary, 60) };
    let mut trainer = Trainer::new(&rt, "160k_ternary", cfg).unwrap();
    let mut batcher = Batcher::new(data.train.clone(),
                                   rt.manifest().train_batch,
                                   rt.manifest().seq, 7);
    trainer.train(&mut batcher, 60, |_| {}).unwrap();
    let ev = Evaluator::new(&rt, "160k_ternary").unwrap();
    let items = eval::generate(&data.world, TaskKind::StereoPairs, 24, 5);
    let score = eval::run_task(&ev, trainer.param_literals(), &data.bpe,
                               TaskKind::StereoPairs, &items).unwrap();
    assert_eq!(score.n, 24);
    assert!(score.acc >= 0.0 && score.acc <= 1.0);
}
