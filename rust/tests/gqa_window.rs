//! The attention-geometry refactor's bitwise acceptance bar: fused
//! QKV/gate-up projections, grouped-query attention, and the
//! sliding-window layer policy must change serving *economics* without
//! ever changing a stream the old geometry could produce.
//!
//! Four equivalences, each held across the four storage families
//! (FloatLM, QuantLM-RTN, QuantLM-GPTQ, TriLM), crossed with chunked
//! prefill (chunks {1, 3, >= prompt}) and a speculative verify span:
//!
//! 1. **Defaults are identity** — `kv_heads == heads` and
//!    `window >= context` (windowed or interleaved) decode bitwise
//!    identically to the untouched builder, greedy and seeded top-k.
//! 2. **GQA == replicated-head MHA** — a `kv_heads < heads` model
//!    matches a classic MHA model whose k/v weights replicate each
//!    shared head across its query group (float storage: replication
//!    preserves rows bitwise; quantized groupings legitimately differ
//!    across matrix shapes, and `serve/model.rs`'s unit tests pin the
//!    per-family fused/GQA algebra).
//! 3. **Fused and separate checkpoint names are one model** — the
//!    `l{i}.attn_qkv` / `l{i}.mlp_gateup` stacks, the separate
//!    `l{i}.attn_{q,k,v}` / `l{i}.mlp_{gate,up}` names, and the
//!    synthetic latent they were sliced from all serve identical
//!    streams in every family.
//! 4. **Windows bound memory, not correctness** — windowed + GQA
//!    models are batch/thread/chunk-invariant and speculative-verify-
//!    invariant, `kv_bytes_per_token` shrinks by exactly the head
//!    ratio, and a windowed lane's `kv_pages_in_use` plateaus at the
//!    window bound while unwindowed (and interleaved-global) lanes
//!    grow with context.

use spectra::checkpoint::Checkpoint;
use spectra::runtime::HostTensor;
use spectra::serve::{DecodeModel, FamilySpec, GenRequest, LatentAttnBlock,
                     LatentAttnLm, LmDims, QuantMethod, Scheduler,
                     SpecConfig};

fn dims() -> LmDims {
    LmDims { vocab: 128, hidden: 64, glu: 96, layers: 3 }
}

/// Heads 4 at hidden 64: head dim 16, so kv_heads ∈ {1, 2, 4} are all
/// legal GQA geometries.
const HEADS: usize = 4;

fn four_families() -> [FamilySpec; 4] {
    [
        FamilySpec::Float,
        FamilySpec::Quant { bits: 3, group: 128, method: QuantMethod::Rtn },
        FamilySpec::Quant { bits: 4, group: 128, method: QuantMethod::Gptq },
        FamilySpec::Ternary,
    ]
}

/// Mixed greedy / seeded top-k traffic: the identity claims must hold
/// under both sampling rules, so half the requests draw from a
/// per-request seeded stream.
fn mixed_requests(n: usize, prompt_len: usize, max_new: usize)
                  -> Vec<GenRequest> {
    (0..n).map(|id| {
        let prompt: Vec<u32> = (0..prompt_len + id % 3)
            .map(|j| ((7 * id + 3 * j + 1) % 128) as u32)
            .collect();
        if id % 2 == 0 {
            GenRequest::greedy(id, prompt, max_new + id % 4)
        } else {
            GenRequest::top_k(id, prompt, max_new + id % 4, 5, 0.9,
                              1000 + id as u64)
        }
    }).collect()
}

fn run_streams(model: &dyn DecodeModel, reqs: &[GenRequest], batch: usize,
               threads: usize, chunk: usize) -> Vec<Vec<u32>> {
    let mut sched = Scheduler::with_prefill_chunk(model, batch, threads,
                                                  chunk);
    for r in reqs {
        sched.submit(r.clone());
    }
    sched.run().into_iter().map(|c| c.tokens).collect()
}

/// Equivalence 1: the geometry knobs at their identity settings —
/// `kv_heads == heads` set explicitly, `window >= context` windowed,
/// `window >= context` with interleaved global layers — decode bitwise
/// identically to the untouched builder in every family, at every
/// (batch, threads, prefill-chunk) combination, greedy and seeded
/// top-k alike.
#[test]
fn identity_geometry_is_bitwise_the_default_model_in_every_family() {
    let reqs = mixed_requests(8, 6, 6); // prompts <= 8, lanes <= 18 tokens
    let variants: [(&str, LatentAttnLm); 3] = [
        ("kv_heads == heads",
         LatentAttnLm::synthetic(dims(), HEADS, 1, 70).with_kv_heads(HEADS)),
        ("window >= context",
         LatentAttnLm::synthetic(dims(), HEADS, 1, 70).with_window(64, 0)),
        ("window >= context + global interleave",
         LatentAttnLm::synthetic(dims(), HEADS, 1, 70).with_window(64, 1)),
    ];
    for spec in four_families() {
        let base = LatentAttnLm::synthetic(dims(), HEADS, 1, 70)
            .build(spec, 8, 24).unwrap();
        let reference = run_streams(base.as_ref(), &reqs, 1, 1, 1);
        assert_eq!(reference.len(), 8, "{}", spec.label());
        for (name, latent) in &variants {
            let model = latent.build(spec, 8, 24).unwrap();
            // Chunks {1, 3, >= prompt} crossed with batch/thread shape.
            for (batch, threads, chunk) in [(1, 1, 1), (4, 2, 3),
                                            (8, 2, 16)] {
                assert_eq!(
                    run_streams(model.as_ref(), &reqs, batch, threads,
                                chunk),
                    reference,
                    "{}: '{name}' diverged from the default model at \
                     batch={batch} threads={threads} chunk={chunk}",
                    spec.label());
            }
        }
    }
}

/// Rows `[kh*dh, (kh+1)*dh)` of the shared projection, replicated once
/// per query head in the group — the classic-MHA weight layout whose
/// attention is algebraically (and, in f32 storage, bitwise) the GQA
/// model's.
fn replicate_shared_heads(w: &HostTensor, kv_heads: usize, group: usize,
                          dh: usize) -> HostTensor {
    let (_, cols) = w.dims2();
    let heads = kv_heads * group;
    let mut data = Vec::with_capacity(heads * dh * cols);
    for h in 0..heads {
        let kh = h / group;
        data.extend_from_slice(w.rows_range(kh * dh, (kh + 1) * dh));
    }
    HostTensor::new(vec![heads * dh, cols], data)
}

/// Equivalence 2: GQA vs a replicated-head MHA reference, end to end
/// through the scheduler. Sharing kv heads across a query group is the
/// same computation as giving every query head a private copy of the
/// shared weights — float storage keeps the comparison bitwise
/// (replication preserves each row; quantized formats group across
/// rows, so their per-family algebra is pinned by the model-level unit
/// tests instead).
#[test]
fn gqa_matches_a_replicated_head_mha_reference() {
    let dh = dims().hidden / HEADS;
    let reqs = mixed_requests(8, 6, 6);
    for kv_heads in [1usize, 2] {
        let group = HEADS / kv_heads;
        let gqa = LatentAttnLm::synthetic(dims(), HEADS, 1, 71)
            .with_kv_heads(kv_heads);
        let base = LatentAttnLm::synthetic(dims(), HEADS, 1, 71);
        let blocks: Vec<LatentAttnBlock> = base.blocks.iter().map(|b| {
            LatentAttnBlock {
                wq: b.wq.clone(),
                wk: replicate_shared_heads(&b.wk, kv_heads, group, dh),
                wv: replicate_shared_heads(&b.wv, kv_heads, group, dh),
                wo: b.wo.clone(),
                gate: b.gate.clone(),
                up: b.up.clone(),
                down: b.down.clone(),
            }
        }).collect();
        let mha = LatentAttnLm {
            dims: dims(), heads: HEADS, kv_heads: HEADS,
            window: 0, window_interleave: 0,
            embed: base.embed.clone(), blocks, head: base.head.clone(),
            mp: 1,
        };
        let gqa_model = gqa.build_float(4, 24);
        let mha_model = mha.build_float(4, 24);
        assert_eq!(run_streams(&gqa_model, &reqs, 4, 2, 3),
                   run_streams(&mha_model, &reqs, 4, 2, 3),
                   "kv_heads={kv_heads}: GQA diverged from its \
                    replicated-head MHA reference");
        // The economics differ even though the streams do not: the
        // replicated model pays full-width KV traffic.
        assert_eq!(gqa_model.kv_bytes_per_token() * group as f64,
                   mha_model.kv_bytes_per_token(),
                   "kv_heads={kv_heads}: KV bytes must shrink by the \
                    head ratio");
    }
}

/// Equivalence 3: fused checkpoint names (`l{i}.attn_qkv`,
/// `l{i}.mlp_gateup`), separate checkpoint names, and the synthetic
/// latent they were sliced from all build bitwise-identical serving
/// models in every family — including GQA shapes, where the kv head
/// count is inferred from the k projection's rows.
#[test]
fn fused_and_separate_checkpoint_names_serve_identical_streams() {
    let kv_heads = 2usize;
    let dh = dims().hidden / HEADS;
    let kv_dim = kv_heads * dh;
    let latent = LatentAttnLm::synthetic(dims(), HEADS, 1, 72)
        .with_kv_heads(kv_heads);

    let first_rows = |w: &HostTensor, n: usize| -> HostTensor {
        HostTensor::new(vec![n, w.dims2().1], w.rows_range(0, n).to_vec())
    };
    let cat_rows = |parts: &[&HostTensor]| -> HostTensor {
        let cols = parts[0].dims2().1;
        let rows: usize = parts.iter().map(|p| p.dims2().0).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for p in parts {
            data.extend_from_slice(&p.data);
        }
        HostTensor::new(vec![rows, cols], data)
    };

    let mut separate = vec![("embed".to_string(), latent.embed.clone()),
                            ("head".to_string(), latent.head.clone())];
    let mut fused = separate.clone();
    for (l, b) in latent.blocks.iter().enumerate() {
        let k = first_rows(&b.wk, kv_dim);
        let v = first_rows(&b.wv, kv_dim);
        separate.push((format!("l{l}.attn_q"), b.wq.clone()));
        separate.push((format!("l{l}.attn_k"), k.clone()));
        separate.push((format!("l{l}.attn_v"), v.clone()));
        fused.push((format!("l{l}.attn_qkv"), cat_rows(&[&b.wq, &k, &v])));
        separate.push((format!("l{l}.mlp_gate"), b.gate.clone()));
        separate.push((format!("l{l}.mlp_up"), b.up.clone()));
        fused.push((format!("l{l}.mlp_gateup"), cat_rows(&[&b.gate,
                                                           &b.up])));
        for target in [&mut separate, &mut fused] {
            target.push((format!("l{l}.attn_o"), b.wo.clone()));
            target.push((format!("l{l}.mlp_down"), b.down.clone()));
        }
    }
    let from_sep = LatentAttnLm::from_checkpoint(
        &Checkpoint::new(separate), HEADS).unwrap();
    let from_fused = LatentAttnLm::from_checkpoint(
        &Checkpoint::new(fused), HEADS).unwrap();
    for l in [&from_sep, &from_fused] {
        assert_eq!(l.kv_heads, kv_heads,
                   "kv head count must be inferred from the k rows");
        assert_eq!(l.dims, dims());
    }

    let reqs = mixed_requests(8, 6, 6);
    for spec in four_families() {
        let reference = run_streams(
            latent.build(spec, 4, 24).unwrap().as_ref(), &reqs, 4, 2, 3);
        for (name, l) in [("separate", &from_sep), ("fused", &from_fused)] {
            assert_eq!(
                run_streams(l.build(spec, 4, 24).unwrap().as_ref(), &reqs,
                            4, 2, 3),
                reference,
                "{}: the {name}-names checkpoint diverged from the \
                 latent it was written from", spec.label());
        }
    }
}

/// Equivalence 4a: a GQA + sliding-window model (window *below* the
/// prompt length, so truncation is live) is still batch-, thread-, and
/// chunk-invariant, and a speculative verify span over the windowed
/// cache changes schedule, never streams — for every target family,
/// with both the all-windowed and the interleaved-global layer policy.
#[test]
fn windowed_gqa_is_chunk_and_speculation_invariant_in_every_family() {
    let reqs = mixed_requests(6, 12, 8); // prompts 12..=14 > window 8
    for interleave in [0usize, 1] {
        let latent = || {
            LatentAttnLm::synthetic(dims(), HEADS, 1, 73)
                .with_kv_heads(2)
                .with_window(8, interleave)
        };
        for spec in four_families() {
            let target = latent().build(spec, 4, 40).unwrap();
            let reference = run_streams(target.as_ref(), &reqs, 1, 1, 1);
            for (batch, threads, chunk) in [(4, 2, 3), (4, 2, 16),
                                            (2, 1, 1)] {
                assert_eq!(
                    run_streams(target.as_ref(), &reqs, batch, threads,
                                chunk),
                    reference,
                    "{} interleave={interleave}: windowed streams \
                     diverged at batch={batch} threads={threads} \
                     chunk={chunk}", spec.label());
            }
            // Speculative verify spans over the windowed, grouped
            // cache: draft from the same latent, same geometry.
            let draft = latent().build(FamilySpec::Ternary, 4, 40).unwrap();
            let mut sched = Scheduler::with_prefill_chunk(
                target.as_ref(), 4, 2, 3);
            sched.set_speculative(draft.as_ref(), SpecConfig {
                draft_family: FamilySpec::Ternary, k: 3 });
            for r in &reqs {
                sched.submit(r.clone());
            }
            let got: Vec<Vec<u32>> =
                sched.run().into_iter().map(|c| c.tokens).collect();
            assert_eq!(got, reference,
                       "{} interleave={interleave}: a speculative \
                        verify span changed a windowed stream",
                       spec.label());
            let st = sched.stats();
            assert!(st.spec_verify_steps > 0,
                    "{}: speculation never engaged", spec.label());
            assert!(st.spec_k_effective >= 1 && st.spec_k_effective <= 3,
                    "{}: adaptive k {} escaped [1, spec_k]",
                    spec.label(), st.spec_k_effective);
            if matches!(spec, FamilySpec::Ternary) {
                assert_eq!(st.spec_accepted, st.spec_proposed,
                           "a bitwise-identical windowed draft must be \
                            fully accepted");
            }
        }
    }
}

/// Equivalence 4b: `kv_bytes_per_token` is exactly the head-ratio-
/// scaled page layout — `2 * layers * kv_heads * dh * 4` bytes — in
/// every storage family (the KV stream is family-independent).
#[test]
fn kv_bytes_per_token_shrinks_by_exactly_the_head_ratio() {
    for spec in four_families() {
        for (kv_heads, want) in [(4usize, 1536.0f64), (2, 768.0),
                                 (1, 384.0)] {
            let model = LatentAttnLm::synthetic(dims(), HEADS, 1, 70)
                .with_kv_heads(kv_heads)
                .build(spec, 1, 16)
                .unwrap();
            assert_eq!(model.kv_bytes_per_token(), want,
                       "{} kv_heads={kv_heads}: expected \
                        2*layers*kv_dim*4 = {want} KV bytes/token",
                       spec.label());
        }
    }
}

/// One lane decoded to `max_new` tokens under the given window policy,
/// returning the peak post-step `kv_pages_in_use` (and asserting the
/// retired lane frees everything).
fn peak_pages(window: usize, interleave: usize, max_new: usize) -> usize {
    let latent = LatentAttnLm::synthetic(dims(), HEADS, 1, 74)
        .with_window(window, interleave);
    let model = latent.build_float(1, 80);
    let mut sched = Scheduler::new(&model, 1, 2);
    let prompt: Vec<u32> = (0..4u32).map(|j| (5 * j + 3) % 128).collect();
    sched.submit(GenRequest::greedy(0, prompt, max_new));
    let mut done = Vec::new();
    let mut peak = 0usize;
    while sched.pending() > 0 {
        sched.step_into(&mut done);
        peak = peak.max(model.kv_pages_in_use());
    }
    assert_eq!(done.len(), 1, "the lane must complete");
    assert_eq!(model.kv_pages_in_use(), 0,
               "a retired windowed lane must free every page");
    peak
}

/// Equivalence 4c (the acceptance assertion): with every layer
/// windowed, a lane's page footprint plateaus at the window bound —
/// doubling the decode length does not move the peak — while the
/// unwindowed model and the interleaved-global policy (whose global
/// layers legitimately need the whole context) grow O(context).
#[test]
fn windowed_lanes_plateau_while_unwindowed_lanes_grow_with_context() {
    // 4-token prompt + 60 new tokens = 64 positions = 4 pages held by
    // the unwindowed model at retirement.
    let full = peak_pages(0, 0, 60);
    assert_eq!(full, 4, "unwindowed lane must hold O(context) pages");

    let windowed_short = peak_pages(16, 0, 28); // 32 positions
    let windowed_long = peak_pages(16, 0, 60);  // 64 positions
    assert_eq!(windowed_short, windowed_long,
               "a windowed lane's peak pages must plateau at the \
                window bound, not grow with decode length");
    assert!(windowed_long < full,
            "window recycling never returned a page \
             (peak {windowed_long} vs unwindowed {full})");
    assert!(windowed_long <= 3,
            "window 16 must bound a lane near ceil(window/page)+1 \
             pages, got {windowed_long}");

    // One global layer pins the whole context: recycling must stay
    // off, because the token-major cache cannot free a page some
    // layer still reads.
    assert_eq!(peak_pages(16, 1, 60), full,
               "an interleaved global layer must block page recycling");
}
