//! Chaos acceptance: deterministic fault injection against the shard
//! worker across all four storage families.
//!
//! The ISSUE-8 bar, end to end:
//!
//! - N scripted mid-stream client disconnects leave `kv_pages_in_use
//!   == 0` (polled live, then re-asserted at drain), `cancelled == N`,
//!   and every *surviving* stream bitwise identical to an undisturbed
//!   direct-scheduler run — for FloatLM, QuantLM-RTN, QuantLM-GPTQ,
//!   and TriLM alike.
//! - An injected worker panic is survived: the supervisor rebuilds the
//!   shard, parked requests complete under the new incarnation, the
//!   dead lane's stream closes promptly (disconnect, never a done
//!   trailer), `/stats` counts the restart, and the drain still holds
//!   zero pages.
//! - Parked requests past the queue-admission deadline expire with an
//!   in-band error line while the lane-holding request is unaffected.
//!
//! Everything here is coordinate-scripted (ticket numbers, token
//! indices, scheduler steps) — no wall-clock races, so the tests are
//! exactly reproducible.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use spectra::serve::{DecodeModel, FamilySpec, FaultPlan, FinishReason,
                     GenRequest, LatentAttnLm, LmDims, QuantMethod,
                     Sampling, Scheduler, SpecConfig};
use spectra::server::{run_shard, run_shard_spec, run_shard_supervised,
                      GenerateBody, ShardConfig, ShardHandle, StreamItem};

fn dims() -> LmDims {
    LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 }
}

fn four_families() -> [FamilySpec; 4] {
    [
        FamilySpec::Float,
        FamilySpec::Quant { bits: 3, group: 128, method: QuantMethod::Rtn },
        FamilySpec::Quant { bits: 4, group: 128, method: QuantMethod::Gptq },
        FamilySpec::Ternary,
    ]
}

/// Build one family's paged-KV attention model with the `Send` bound a
/// worker thread needs (same concrete-builder match as the server's
/// own model factory).
fn build_send(latent: &LatentAttnLm, spec: FamilySpec, lanes: usize,
              ctx: usize, seed: u64) -> Box<dyn DecodeModel + Send> {
    match spec {
        FamilySpec::Float => Box::new(latent.build_float(lanes, ctx)),
        FamilySpec::Ternary => Box::new(latent.build_ternary(lanes, ctx)),
        FamilySpec::Quant { bits, group, method: QuantMethod::Rtn } =>
            Box::new(latent.build_quant_rtn(bits, group, lanes, ctx)),
        FamilySpec::Quant { bits, group, method: QuantMethod::Gptq } =>
            Box::new(latent.build_quant_gptq(bits, group, seed, lanes, ctx)
                     .expect("gptq calibration on synthetic weights")),
    }
}

fn body(tenant: &str, prompt: Vec<u32>, max_new: usize) -> GenerateBody {
    GenerateBody {
        prompt,
        max_new_tokens: max_new,
        tenant: tenant.to_string(),
        sampling: Sampling::Greedy,
    }
}

/// Poll the handle until the worker publishes zero live lanes and zero
/// KV pages — the "pages came back without waiting for drain" check.
fn wait_pages_free(h: &ShardHandle, what: &str) {
    let t0 = Instant::now();
    loop {
        let s = h.snapshot(0);
        if s.live_lanes == 0 && s.kv_pages == 0 && s.queue_depth == 0 {
            return;
        }
        assert!(t0.elapsed() < Duration::from_secs(30),
                "{what}: pages/lanes still held after 30s \
                 (kv_pages {}, live_lanes {}, queue {})",
                s.kv_pages, s.live_lanes, s.queue_depth);
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn scripted_disconnects_free_pages_and_leave_survivors_bitwise_intact() {
    let seed = 0xC405;
    let lanes = 2;
    let ctx = 32;
    let max_new = 6;
    let prompts: Vec<Vec<u32>> =
        (0..6u32).map(|i| vec![i + 1, i + 9, i + 17]).collect();
    // Tickets are admission-sequential, so these coordinates are exact:
    // client 1 hangs up once it has token index 1, client 4 after
    // token index 0.
    let cuts: Vec<(usize, usize)> = vec![(1, 1), (4, 0)];

    for spec in four_families() {
        let latent = LatentAttnLm::synthetic(dims(), 4, 1, seed);

        // Undisturbed reference: same prompts, direct scheduler, same
        // family build.
        let clean = build_send(&latent, spec, lanes, ctx, seed);
        let mut sched = Scheduler::new(&*clean, lanes, 1);
        for (id, p) in prompts.iter().enumerate() {
            sched.submit(GenRequest::greedy(id, p.clone(), max_new));
        }
        let mut expect: HashMap<Vec<u32>, Vec<u32>> = HashMap::new();
        for c in sched.run() {
            expect.insert(prompts[c.id].clone(), c.tokens);
        }

        // Chaos run: same traffic through the shard worker with two
        // scripted mid-stream disconnects.
        let h = Arc::new(ShardHandle::new(16));
        let model = build_send(&latent, spec, lanes, ctx, seed);
        let cfg = ShardConfig {
            lanes,
            threads: 1,
            prefill_chunk: 1,
            faults: FaultPlan {
                disconnect_at: cuts.clone(),
                ..FaultPlan::default()
            },
            ..ShardConfig::default()
        };
        let worker = {
            let h = h.clone();
            std::thread::spawn(move || run_shard(model, &h, &cfg))
        };
        let mut rxs = Vec::new();
        for p in &prompts {
            let (tx, rx) = mpsc::channel();
            let ticket = h.try_admit(body("t", p.clone(), max_new), tx)
                .expect("admission under cap");
            rxs.push((ticket, p.clone(), rx));
        }
        for (ticket, prompt, rx) in rxs {
            let cut = cuts.iter().find(|(t, _)| *t == ticket)
                .map(|&(_, i)| i);
            let mut streamed: Vec<u32> = Vec::new();
            let mut finished = None;
            loop {
                match rx.recv_timeout(Duration::from_secs(30)) {
                    Ok(StreamItem::Token { token, index }) => {
                        assert_eq!(index, streamed.len(),
                                   "{spec:?}: in-order deduped stream");
                        streamed.push(token);
                    }
                    Ok(StreamItem::Done(c)) => {
                        finished = Some(c);
                        break;
                    }
                    Ok(StreamItem::Error { kind, detail }) => {
                        panic!("{spec:?}: unexpected error line \
                                {kind}: {detail}");
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    Err(e) => panic!("{spec:?}: stream stalled ({e})"),
                }
            }
            match cut {
                Some(cut) => {
                    assert!(finished.is_none(),
                            "{spec:?}: a disconnected client must not \
                             get a done trailer");
                    assert_eq!(streamed.len(), cut + 1,
                               "{spec:?}: the stream cuts right after \
                                the scripted token index");
                    assert_eq!(streamed[..], expect[&prompt][..cut + 1],
                               "{spec:?}: tokens before the cut are the \
                                clean stream's prefix");
                }
                None => {
                    let c = finished.unwrap_or_else(|| panic!(
                        "{spec:?}: survivor stream ended without done"));
                    assert_eq!(c.finish_reason, FinishReason::Length);
                    assert_eq!(streamed, expect[&prompt],
                               "{spec:?}: surviving streams must be \
                                bitwise identical to the undisturbed \
                                run");
                }
            }
        }
        // Pages come back from the cancels without waiting for drain.
        wait_pages_free(&h, "post-disconnect");
        h.request_shutdown();
        assert_eq!(worker.join().unwrap(), 0,
                   "{spec:?}: zero pages after drain");
        let s = h.snapshot(0);
        assert_eq!(s.cancelled, cuts.len(),
                   "{spec:?}: every scripted disconnect is one cancel");
        assert_eq!(s.served, prompts.len() - cuts.len());
        assert_eq!(s.worker_restarts, 0);
    }
}

#[test]
fn injected_panic_restarts_the_worker_and_spares_parked_requests() {
    let seed = 0xC406;
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, seed);
    let h = Arc::new(ShardHandle::new(16));
    let cfg = ShardConfig {
        lanes: 1,
        threads: 1,
        prefill_chunk: 4,
        faults: FaultPlan {
            panic_after_step: Some(2),
            ..FaultPlan::default()
        },
        ..ShardConfig::default()
    };
    // One live victim, one parked survivor.
    let (tx_a, rx_a) = mpsc::channel();
    h.try_admit(body("t", vec![5, 6], 8), tx_a).unwrap();
    let (tx_b, rx_b) = mpsc::channel();
    h.try_admit(body("t", vec![7, 8], 3), tx_b).unwrap();
    let worker = {
        let h = h.clone();
        let latent = LatentAttnLm::synthetic(dims(), 4, 1, seed);
        std::thread::spawn(move || {
            run_shard_supervised(
                || build_send(&latent, FamilySpec::Float, 1, 32, seed),
                &h, &cfg)
        })
    };
    // The survivor completes under the rebuilt incarnation.
    let mut b_tokens = Vec::new();
    loop {
        let item = rx_b.recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|e| panic!("survivor stalled ({e})"));
        match item {
            StreamItem::Token { token, .. } => b_tokens.push(token),
            StreamItem::Done(c) => {
                assert_eq!(c.tokens, b_tokens);
                assert_eq!(c.tokens.len(), 3,
                           "survivor decodes its full budget after the \
                            restart");
                assert_eq!(c.finish_reason, FinishReason::Length);
                break;
            }
            StreamItem::Error { kind, detail } => {
                panic!("survivor hit error {kind}: {detail}");
            }
        }
    }
    // The victim's stream died with the worker: channel disconnects
    // promptly, no done trailer ever arrives.
    let mut a_done = false;
    while let Ok(item) = rx_a.recv_timeout(Duration::from_secs(5)) {
        if matches!(item, StreamItem::Done(_)) {
            a_done = true;
        }
    }
    assert!(!a_done, "the lane live at panic time must not complete");
    h.request_shutdown();
    assert_eq!(worker.join().unwrap(), 0,
               "the rebuilt model must drain with zero pages — the dead \
                incarnation's pool died with it");
    let s = h.snapshot(0);
    assert_eq!(s.worker_restarts, 1);
    assert_eq!(s.served, 1);
    assert_eq!(s.queue_depth, 0);
    // The reference latent decodes the survivor identically: restart
    // rebuilds bitwise-identical weights from the same seed.
    let clean = build_send(&latent, FamilySpec::Float, 1, 32, seed);
    let mut sched = Scheduler::new(&*clean, 1, 1);
    sched.submit(GenRequest::greedy(0, vec![7, 8], 3));
    assert_eq!(sched.run().remove(0).tokens, b_tokens,
               "post-restart decode must match a fresh model bitwise");
}

#[test]
fn queue_deadline_expires_parked_requests_under_a_busy_lane() {
    let seed = 0xC407;
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, seed);
    let h = Arc::new(ShardHandle::new(16));
    let cfg = ShardConfig {
        lanes: 1,
        threads: 1,
        prefill_chunk: 1,
        queue_deadline: Some(Duration::from_millis(0)),
        ..ShardConfig::default()
    };
    // Deterministic setup, no wall-clock race: the lane holder is
    // admitted *before* the worker installs the queue deadline, so its
    // deadline stamp is `None` (immune to expiry); everything admitted
    // after it carries a 0ms deadline — already due by the worker's
    // next sweep, which runs *before* the feed stage, so a parked
    // request can never sneak into the freed lane instead of expiring.
    let (tx_live, rx_live) = mpsc::channel();
    h.try_admit(body("t", vec![3, 4], 48), tx_live).unwrap();
    let model = build_send(&latent, FamilySpec::Float, 1, 64, seed);
    let worker = {
        let h = h.clone();
        std::thread::spawn(move || run_shard(model, &h, &cfg))
    };
    // Wait until the holder is actually streaming: its first token
    // proves the worker is up and the deadline is installed.
    let first = rx_live.recv_timeout(Duration::from_secs(30));
    assert!(matches!(first, Ok(StreamItem::Token { .. })),
            "lane holder must start streaming");
    let mut parked_rx = Vec::new();
    for i in 0..2u32 {
        let (tx, rx) = mpsc::channel();
        h.try_admit(body("t", vec![10 + i], 4), tx).unwrap();
        parked_rx.push(rx);
    }
    for rx in parked_rx {
        let item = rx.recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|e| panic!("parked request got nothing ({e})"));
        match item {
            StreamItem::Error { kind, .. } => {
                assert_eq!(kind, "deadline_expired");
            }
            other => panic!("parked request must expire with an error \
                             line, got {other:?}"),
        }
    }
    // The lane holder is unaffected: full budget, normal finish.
    let mut live_tokens = 1usize; // the token consumed above
    loop {
        let item = rx_live.recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|e| panic!("lane holder stalled ({e})"));
        match item {
            StreamItem::Token { .. } => live_tokens += 1,
            StreamItem::Done(c) => {
                assert_eq!(c.finish_reason, FinishReason::Length);
                assert_eq!(c.tokens.len(), 48);
                assert_eq!(c.tokens.len(), live_tokens);
                break;
            }
            StreamItem::Error { kind, detail } => {
                panic!("lane holder hit error {kind}: {detail}");
            }
        }
    }
    h.request_shutdown();
    assert_eq!(worker.join().unwrap(), 0);
    let s = h.snapshot(0);
    assert_eq!(s.deadline_expired, 2);
    assert_eq!(s.served, 1);
    assert_eq!(s.cancelled, 0);
}

#[test]
fn speculative_cancel_and_expire_mid_verify_free_both_caches() {
    // ISSUE-9 chaos bar, scheduler-level: cancel one speculative lane
    // and deadline-expire another *between verify rounds* — while both
    // hold committed pages in the target cache and proposal feed in
    // the draft cache. Both models' pages must come back immediately
    // (before the next step runs anything), the expired lane's
    // truncated stream must be a prefix of the non-speculative control
    // stream, and the survivors — including a request admitted into a
    // freed lane *after* the chaos — must stay bitwise intact.
    let seed = 0xC408;
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, seed);
    let target = latent.build_float(4, 32);
    let draft = latent.build_ternary(4, 32);
    let prompts: Vec<Vec<u32>> =
        (0..4u32).map(|i| vec![i + 1, i + 9, i + 17]).collect();
    let max_new = 8;

    // Non-speculative control: the losslessness contract says the
    // speculative streams must match these bitwise.
    let mut control_sched = Scheduler::new(&target, 3, 1);
    for (id, p) in prompts.iter().enumerate() {
        control_sched.submit(GenRequest::greedy(id, p.clone(), max_new));
    }
    let mut control: HashMap<usize, Vec<u32>> = HashMap::new();
    for c in control_sched.run() {
        control.insert(c.id, c.tokens);
    }
    drop(control_sched);
    assert_eq!(target.kv_pages_in_use(), 0);

    // Speculative run: 3 lanes live (ids 0..2), id 3 parked. Step
    // until at least one verify round has executed, so the chaos lands
    // mid-verify with real draft state in play.
    let mut sched = Scheduler::new(&target, 3, 1);
    sched.set_speculative(&draft,
                          SpecConfig { draft_family: FamilySpec::Ternary,
                                       k: 3 });
    for (id, p) in prompts.iter().enumerate() {
        sched.submit(GenRequest::greedy(id, p.clone(), max_new));
    }
    let mut done = Vec::new();
    let mut steps = 0;
    while sched.stats().spec_verify_steps == 0 {
        done.extend(sched.step());
        steps += 1;
        assert!(steps < 10, "no verify round within 10 steps");
    }
    assert_eq!(sched.live_lanes(), 3,
               "budget 8 at k=3 cannot finish in one verify round");
    let target_before = target.kv_pages_in_use();
    let draft_before = draft.kv_pages_in_use();
    assert!(target_before > 0, "live lanes must hold target pages");
    assert!(draft_before > 0, "decode-phase speculative lanes must \
                               hold draft feed pages");

    assert!(sched.cancel(0), "live speculative lane must cancel");
    let expired = sched.expire(1).expect("live lane must expire");
    assert_eq!(expired.finish_reason, FinishReason::DeadlineExpired);
    assert!(!expired.tokens.is_empty(),
            "a lane past its first verify round has delivered tokens");
    assert_eq!(expired.tokens[..], control[&1][..expired.tokens.len()],
               "the truncated stream is a control-stream prefix");
    // Both caches gave the two retired lanes' pages back *now*, not at
    // drain — one lane's worth remains in each.
    assert!(target.kv_pages_in_use() < target_before,
            "cancel/expire must free target pages immediately");
    assert!(draft.kv_pages_in_use() < draft_before,
            "cancel/expire must free draft pages immediately");

    done.extend(sched.run());
    done.sort_by_key(|c| c.id);
    let ids: Vec<usize> = done.iter().map(|c| c.id).collect();
    assert_eq!(ids, vec![2, 3],
               "survivor and post-chaos admission complete; the \
                cancelled lane yields nothing");
    for c in &done {
        assert_eq!(c.tokens, control[&c.id],
                   "request {}: surviving speculative stream diverged \
                    from the non-speculative control", c.id);
        assert_eq!(c.finish_reason, FinishReason::Length);
    }
    let st = sched.stats();
    assert_eq!(st.cancelled, 1);
    assert_eq!(st.deadline_expired, 1);
    assert!(st.spec_proposed > 0);
    assert_eq!(target.kv_pages_in_use(), 0,
               "target pages leaked after speculative chaos");
    assert_eq!(draft.kv_pages_in_use(), 0,
               "draft pages leaked after speculative chaos");
}

#[test]
fn scripted_disconnect_cancels_a_speculative_lane_through_the_worker() {
    // Server-path variant: a scripted mid-stream client disconnect
    // lands on a speculative lane (TriLM drafting for a GPTQ target).
    // A speculative step can deliver several tokens, so the cut client
    // sees at least `cut + 1` tokens — always a prefix of the
    // non-speculative control stream — the lane cancels, and the
    // worker's combined target+draft page count drains to zero.
    let seed = 0xC409;
    let lanes = 2;
    let ctx = 32;
    let max_new = 6;
    let gptq = FamilySpec::Quant { bits: 4, group: 128,
                                   method: QuantMethod::Gptq };
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, seed);
    let prompts: Vec<Vec<u32>> =
        (0..4u32).map(|i| vec![i + 2, i + 11, i + 23]).collect();
    let cut_ticket = 1usize;
    let cut_index = 1usize;

    let clean = build_send(&latent, gptq, lanes, ctx, seed);
    let mut control_sched = Scheduler::new(&*clean, lanes, 1);
    for (id, p) in prompts.iter().enumerate() {
        control_sched.submit(GenRequest::greedy(id, p.clone(), max_new));
    }
    let mut expect: HashMap<usize, Vec<u32>> = HashMap::new();
    for c in control_sched.run() {
        expect.insert(c.id, c.tokens);
    }

    let h = Arc::new(ShardHandle::new(16));
    let model = build_send(&latent, gptq, lanes, ctx, seed);
    let draft: Box<dyn DecodeModel + Send> =
        Box::new(latent.build_ternary(lanes, ctx));
    let cfg = ShardConfig {
        lanes,
        threads: 1,
        prefill_chunk: 1,
        faults: FaultPlan {
            disconnect_at: vec![(cut_ticket, cut_index)],
            ..FaultPlan::default()
        },
        spec: Some(SpecConfig { draft_family: FamilySpec::Ternary, k: 3 }),
        ..ShardConfig::default()
    };
    let worker = {
        let h = h.clone();
        std::thread::spawn(move || run_shard_spec(model, Some(draft),
                                                  &h, &cfg))
    };
    let mut rxs = Vec::new();
    for p in &prompts {
        let (tx, rx) = mpsc::channel();
        let ticket = h.try_admit(body("t", p.clone(), max_new), tx)
            .expect("admission under cap");
        rxs.push((ticket, rx));
    }
    for (ticket, rx) in rxs {
        let mut streamed: Vec<u32> = Vec::new();
        let mut finished = None;
        loop {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(StreamItem::Token { token, index }) => {
                    assert_eq!(index, streamed.len());
                    streamed.push(token);
                }
                Ok(StreamItem::Done(c)) => {
                    finished = Some(c);
                    break;
                }
                Ok(StreamItem::Error { kind, detail }) => {
                    panic!("unexpected error line {kind}: {detail}");
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
                Err(e) => panic!("stream stalled ({e})"),
            }
        }
        if ticket == cut_ticket {
            assert!(finished.is_none(),
                    "a disconnected client must not get a done trailer");
            assert!(streamed.len() > cut_index,
                    "the cut lands only after the scripted token index");
            assert!(streamed.len() <= expect[&ticket].len());
            assert_eq!(streamed[..], expect[&ticket][..streamed.len()],
                       "tokens before the speculative cut are the \
                        control stream's prefix");
        } else {
            let c = finished.unwrap_or_else(|| panic!(
                "survivor {ticket} ended without done"));
            assert_eq!(c.finish_reason, FinishReason::Length);
            assert_eq!(streamed, expect[&ticket],
                       "survivor {ticket}: speculative stream diverged \
                        from the non-speculative control");
        }
    }
    // Both caches' pages come back from the cancel without waiting for
    // drain: the published gauge sums target and draft pools.
    wait_pages_free(&h, "post-speculative-disconnect");
    h.request_shutdown();
    assert_eq!(worker.join().unwrap(), 0,
               "zero combined target+draft pages after drain");
    let s = h.snapshot(0);
    assert_eq!(s.cancelled, 1);
    assert_eq!(s.served, prompts.len() - 1);
    assert!(s.sched.spec_proposed > 0,
            "the worker must actually have run speculative rounds");
    assert!(s.sched.spec_accepted <= s.sched.spec_proposed);
}
