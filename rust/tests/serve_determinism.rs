//! Scheduler determinism: the same request set must produce identical
//! token streams regardless of batch size and kernel thread count —
//! continuous batching is an operational optimization, never a
//! semantic one — and that must hold for *every* storage family the
//! engine serves (FloatLM f32, QuantLM 3/4-bit, TriLM ternary).
//!
//! This holds because (a) each lane's computation depends only on its
//! own state/tokens, (b) every blocked kernel's accumulation order is
//! batch- and thread-invariant (tests/kernel_equivalence.rs checks
//! both the ternary and the k-bit quant kernel bitwise), and (c)
//! greedy ties break by token id while top-k draws from a per-request
//! seeded stream.
//!
//! The scheduler now executes on a persistent worker pool with reused
//! decode scratch (see `runtime::pool`); every test here therefore
//! also exercises the pooled hot path, and the suite additionally
//! cross-checks it against the allocating scoped-thread
//! `step_batch` reference end-to-end.

use spectra::serve::{DecodeModel, FamilySpec, GenRequest, LatentAttnLm,
                     LatentLm, LmDims, QuantMethod, Sampling, Scheduler,
                     TernaryLm};

fn dims() -> LmDims {
    LmDims { vocab: 128, hidden: 64, glu: 96, layers: 3 }
}

/// The four serving families of the acceptance bar. Group 128 at these
/// dims exercises the ragged-group path (hidden 64, glu 96 < 128).
fn four_families() -> [FamilySpec; 4] {
    [
        FamilySpec::Float,
        FamilySpec::Quant { bits: 3, group: 128, method: QuantMethod::Rtn },
        FamilySpec::Quant { bits: 4, group: 128, method: QuantMethod::Rtn },
        FamilySpec::Ternary,
    ]
}

fn request_set() -> Vec<GenRequest> {
    (0..12).map(|id| {
        let prompt: Vec<u32> =
            (0..(1 + id % 5)).map(|j| ((7 * id + 3 * j) % 128) as u32).collect();
        GenRequest::greedy(id, prompt, 4 + id % 7)
    }).collect()
}

fn run(lm: &TernaryLm, max_batch: usize, threads: usize) -> Vec<Vec<u32>> {
    let mut sched = Scheduler::new(lm, max_batch, threads);
    for r in request_set() {
        sched.submit(r);
    }
    sched.run().into_iter().map(|c| c.tokens).collect()
}

#[test]
fn greedy_streams_identical_at_batch_1_and_8() {
    let (lm, _) = TernaryLm::synthetic_pair(dims(), 1, 42);
    let solo = run(&lm, 1, 1);
    let batched = run(&lm, 8, 4);
    assert_eq!(solo.len(), 12);
    for (id, (a, b)) in solo.iter().zip(batched.iter()).enumerate() {
        assert_eq!(a, b, "request {id}: batch-1 and batch-8 streams differ");
    }
}

#[test]
fn greedy_streams_invariant_across_lane_counts_and_threads() {
    let (lm, _) = TernaryLm::synthetic_pair(dims(), 2, 43);
    let reference = run(&lm, 1, 1);
    for (max_batch, threads) in [(2, 1), (3, 2), (5, 3), (12, 8)] {
        let got = run(&lm, max_batch, threads);
        assert_eq!(got, reference,
                   "divergence at max_batch={max_batch} threads={threads}");
    }
}

#[test]
fn every_family_is_batch_and_thread_invariant() {
    // The family-complete acceptance bar: FloatLM, QuantLM 3-bit,
    // QuantLM 4-bit and TriLM storage of the same latent weights all
    // serve deterministically across lane counts and thread counts.
    let latent = LatentLm::synthetic(dims(), 1, 47);
    for spec in four_families() {
        let model = latent.build(spec).unwrap();
        let run_model = |max_batch: usize, threads: usize| -> Vec<Vec<u32>> {
            let mut sched = Scheduler::new(model.as_ref(), max_batch, threads);
            for r in request_set() {
                sched.submit(r);
            }
            sched.run().into_iter().map(|c| c.tokens).collect()
        };
        let reference = run_model(1, 1);
        assert_eq!(reference.len(), 12, "{}", spec.label());
        for (max_batch, threads) in [(8, 4), (3, 2), (12, 8)] {
            assert_eq!(run_model(max_batch, threads), reference,
                       "{}: divergence at max_batch={max_batch} \
                        threads={threads}", spec.label());
        }
    }
}

#[test]
fn families_share_traffic_but_not_streams() {
    // Sanity that the families are genuinely different models in
    // storage: identical latent weights, yet the quantized streams
    // must not all collapse to the float stream (quantization moves
    // near-ties), while every stream stays within the vocab.
    let latent = LatentLm::synthetic(dims(), 1, 48);
    let streams: Vec<Vec<Vec<u32>>> = four_families().iter().map(|&spec| {
        let model = latent.build(spec).unwrap();
        let mut sched = Scheduler::new(model.as_ref(), 4, 2);
        for r in request_set() {
            sched.submit(r);
        }
        sched.run().into_iter().map(|c| c.tokens).collect()
    }).collect();
    for fam in &streams {
        for toks in fam {
            assert!(toks.iter().all(|&t| t < 128));
        }
    }
    // 3-bit is the most perturbed family; it should diverge from float
    // somewhere across 12 requests.
    assert_ne!(streams[0], streams[1],
               "3-bit quantization changed nothing — storage formats \
                are not actually being exercised");
}

#[test]
fn pooled_scheduler_matches_allocating_step_batch_reference() {
    // End-to-end cross-check of the execution substrates: greedy
    // streams from the pooled scheduler (WorkerPool + DecodeScratch)
    // must be identical to a manual decode loop over the allocating
    // scoped-thread `step_batch` — for every storage family.
    let latent = LatentLm::synthetic(dims(), 1, 49);
    for spec in four_families() {
        let model = latent.build(spec).unwrap();
        for req in request_set() {
            // Manual reference: one lane, allocating path.
            let mut state = vec![0.0f32; dims().hidden];
            let mut reference = Vec::new();
            let mut next = req.prompt[0];
            let mut pos = 1usize;
            while reference.len() < req.max_new_tokens {
                let mut refs = [state.as_mut_slice()];
                let logits = model.step_batch(&mut refs, &[next], 2);
                if pos < req.prompt.len() {
                    next = req.prompt[pos];
                    pos += 1;
                } else {
                    let row = logits.row(0);
                    let mut best = 0usize;
                    for (i, &v) in row.iter().enumerate() {
                        if v > row[best] {
                            best = i;
                        }
                    }
                    reference.push(best as u32);
                    next = best as u32;
                }
            }
            assert!(matches!(req.sampling, Sampling::Greedy));
            let mut sched = Scheduler::new(model.as_ref(), 4, 2);
            let id = req.id;
            sched.submit(req);
            let done = sched.run();
            assert_eq!(done[0].tokens, reference,
                       "{}: request {id} diverges between the pooled \
                        scheduler and the allocating step_batch",
                       spec.label());
        }
    }
}

/// Cache capacity for the attention tests: request_set() prompts are
/// 1..=5 tokens with 4..=10 new tokens, so a lane holds at most 14
/// positions; 16 adds headroom.
const ATTN_CTX: usize = 16;

#[test]
fn attn_every_family_is_batch_and_thread_invariant() {
    // The tentpole acceptance bar: the paged KV-cache attention model
    // serves all four families (FloatLM, QuantLM-RTN, QuantLM-GPTQ,
    // TriLM) through the unmodified scheduler with token streams
    // identical at batch 1 and batch max, across thread counts. One
    // model instance per family is reused across all runs — lane churn
    // recycles its pages, and recycling must be invisible.
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 50);
    let specs = [
        FamilySpec::Float,
        FamilySpec::Quant { bits: 3, group: 128, method: QuantMethod::Rtn },
        FamilySpec::Quant { bits: 4, group: 128, method: QuantMethod::Gptq },
        FamilySpec::Ternary,
    ];
    for spec in specs {
        let model = latent.build(spec, 12, ATTN_CTX).unwrap();
        let run_model = |max_batch: usize, threads: usize| -> Vec<Vec<u32>> {
            let mut sched = Scheduler::new(model.as_ref(), max_batch, threads);
            for r in request_set() {
                sched.submit(r);
            }
            sched.run().into_iter().map(|c| c.tokens).collect()
        };
        let reference = run_model(1, 1);
        assert_eq!(reference.len(), 12, "{}", spec.label());
        for (max_batch, threads) in [(8, 4), (3, 2), (12, 8)] {
            assert_eq!(run_model(max_batch, threads), reference,
                       "attn {}: divergence at max_batch={max_batch} \
                        threads={threads}", spec.label());
        }
    }
}

#[test]
fn attn_pooled_scheduler_matches_allocating_step_batch_reference() {
    // End-to-end substrate cross-check for the attention path: greedy
    // streams from the pooled scheduler must match a manual decode
    // loop over the allocating scoped-thread `step_batch`. Two model
    // instances per family (the KV cache is stateful); the manual
    // instance is sized for all 12 requests since its lanes are never
    // retired.
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 53);
    for spec in [FamilySpec::Float, FamilySpec::Ternary] {
        let sched_model = latent.build(spec, 4, ATTN_CTX).unwrap();
        let manual_model = latent.build(spec, 12, ATTN_CTX).unwrap();
        for req in request_set() {
            let mut state = vec![0.0f32; dims().hidden];
            let mut reference = Vec::new();
            let mut next = req.prompt[0];
            let mut pos = 1usize;
            while reference.len() < req.max_new_tokens {
                let mut refs = [state.as_mut_slice()];
                let logits = manual_model.step_batch(&mut refs, &[next], 2);
                if pos < req.prompt.len() {
                    next = req.prompt[pos];
                    pos += 1;
                } else {
                    let row = logits.row(0);
                    let mut best = 0usize;
                    for (i, &v) in row.iter().enumerate() {
                        if v > row[best] {
                            best = i;
                        }
                    }
                    reference.push(best as u32);
                    next = best as u32;
                }
            }
            let mut sched = Scheduler::new(sched_model.as_ref(), 4, 2);
            let id = req.id;
            sched.submit(req);
            let done = sched.run();
            assert_eq!(done[0].tokens, reference,
                       "attn {}: request {id} diverges between the pooled \
                        scheduler and the allocating step_batch",
                       spec.label());
        }
    }
}

#[test]
fn attn_streams_differ_from_decay_streams() {
    // Attention is a different context mechanism, not a relabeling:
    // the same latent MLP discipline with a KV cache must produce
    // different greedy streams than the decay-state model somewhere
    // across the request set (both stay in-vocab).
    let decay = LatentLm::synthetic(dims(), 1, 54).build_float();
    let attn = LatentAttnLm::synthetic(dims(), 4, 1, 54)
        .build(FamilySpec::Float, 4, ATTN_CTX).unwrap();
    let run_any = |m: &dyn DecodeModel| -> Vec<Vec<u32>> {
        let mut sched = Scheduler::new(m, 4, 2);
        for r in request_set() {
            sched.submit(r);
        }
        sched.run().into_iter().map(|c| c.tokens).collect()
    };
    let a = run_any(&decay);
    let b = run_any(attn.as_ref());
    for fam in [&a, &b] {
        for toks in fam {
            assert!(toks.iter().all(|&t| t < 128));
        }
    }
    assert_ne!(a, b, "attention model decoded exactly like the decay \
                      model — the cache is not being exercised");
}

#[test]
fn dense_twin_is_also_batch_invariant() {
    // The contract is on the engine, not just the ternary kernels: the
    // dense baseline must serve deterministically too.
    let (_, dlm) = TernaryLm::synthetic_pair(dims(), 1, 44);
    let run_dense = |max_batch: usize| -> Vec<Vec<u32>> {
        let mut sched = Scheduler::new(&dlm, max_batch, 1);
        for r in request_set() {
            sched.submit(r);
        }
        sched.run().into_iter().map(|c| c.tokens).collect()
    };
    assert_eq!(run_dense(1), run_dense(8));
}

#[test]
fn top_k_streams_identical_at_batch_1_and_8() {
    // Seeded top-k: the random draw sequence is per-request, so batch
    // composition cannot perturb it.
    let (lm, _) = TernaryLm::synthetic_pair(dims(), 1, 45);
    let run_topk = |max_batch: usize| -> Vec<Vec<u32>> {
        let mut sched = Scheduler::new(&lm, max_batch, 2);
        for id in 0..10 {
            sched.submit(GenRequest::top_k(
                id, vec![(id as u32) % 128, 9], 6, 5, 0.9, 1000 + id as u64));
        }
        sched.run().into_iter().map(|c| c.tokens).collect()
    };
    assert_eq!(run_topk(1), run_topk(8));
}

#[test]
fn ternary_and_dense_serve_comparable_distributions() {
    // Weight-identical twins: greedy streams may legitimately diverge
    // at near-ties, but the first decoded token (one step from a zero
    // state) must agree — a storage-format smoke check at the serving
    // level.
    let (tlm, dlm) = TernaryLm::synthetic_pair(dims(), 1, 46);
    let first = |out: Vec<Vec<u32>>| -> Vec<u32> {
        out.into_iter().map(|t| t[0]).collect()
    };
    let mut st = Scheduler::new(&tlm, 4, 1);
    let mut sd = Scheduler::new(&dlm, 4, 1);
    for r in request_set() {
        st.submit(r.clone());
        sd.submit(r);
    }
    let a = first(st.run().into_iter().map(|c| c.tokens).collect());
    let b = first(sd.run().into_iter().map(|c| c.tokens).collect());
    let agree = a.iter().zip(b.iter()).filter(|(x, y)| x == y).count();
    assert!(agree >= 10, "only {agree}/12 first tokens agree");
}
