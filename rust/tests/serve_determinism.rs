//! Scheduler determinism (satellite): the same request set must
//! produce identical token streams regardless of batch size and
//! kernel thread count — continuous batching is an operational
//! optimization, never a semantic one.
//!
//! This holds because (a) each lane's computation depends only on its
//! own state/tokens, (b) the blocked kernel's accumulation order is
//! batch- and thread-invariant (tests/kernel_equivalence.rs checks it
//! bitwise), and (c) greedy ties break by token id while top-k draws
//! from a per-request seeded stream.

use spectra::serve::{GenRequest, LmDims, Scheduler, TernaryLm};

fn dims() -> LmDims {
    LmDims { vocab: 128, hidden: 64, glu: 96, layers: 3 }
}

fn request_set() -> Vec<GenRequest> {
    (0..12).map(|id| {
        let prompt: Vec<u32> =
            (0..(1 + id % 5)).map(|j| ((7 * id + 3 * j) % 128) as u32).collect();
        GenRequest::greedy(id, prompt, 4 + id % 7)
    }).collect()
}

fn run(lm: &TernaryLm, max_batch: usize, threads: usize) -> Vec<Vec<u32>> {
    let mut sched = Scheduler::new(lm, max_batch, threads);
    for r in request_set() {
        sched.submit(r);
    }
    sched.run().into_iter().map(|c| c.tokens).collect()
}

#[test]
fn greedy_streams_identical_at_batch_1_and_8() {
    let (lm, _) = TernaryLm::synthetic_pair(dims(), 1, 42);
    let solo = run(&lm, 1, 1);
    let batched = run(&lm, 8, 4);
    assert_eq!(solo.len(), 12);
    for (id, (a, b)) in solo.iter().zip(batched.iter()).enumerate() {
        assert_eq!(a, b, "request {id}: batch-1 and batch-8 streams differ");
    }
}

#[test]
fn greedy_streams_invariant_across_lane_counts_and_threads() {
    let (lm, _) = TernaryLm::synthetic_pair(dims(), 2, 43);
    let reference = run(&lm, 1, 1);
    for (max_batch, threads) in [(2, 1), (3, 2), (5, 3), (12, 8)] {
        let got = run(&lm, max_batch, threads);
        assert_eq!(got, reference,
                   "divergence at max_batch={max_batch} threads={threads}");
    }
}

#[test]
fn dense_twin_is_also_batch_invariant() {
    // The contract is on the engine, not just the ternary kernels: the
    // dense baseline must serve deterministically too.
    let (_, dlm) = TernaryLm::synthetic_pair(dims(), 1, 44);
    let run_dense = |max_batch: usize| -> Vec<Vec<u32>> {
        let mut sched = Scheduler::new(&dlm, max_batch, 1);
        for r in request_set() {
            sched.submit(r);
        }
        sched.run().into_iter().map(|c| c.tokens).collect()
    };
    assert_eq!(run_dense(1), run_dense(8));
}

#[test]
fn top_k_streams_identical_at_batch_1_and_8() {
    // Seeded top-k: the random draw sequence is per-request, so batch
    // composition cannot perturb it.
    let (lm, _) = TernaryLm::synthetic_pair(dims(), 1, 45);
    let run_topk = |max_batch: usize| -> Vec<Vec<u32>> {
        let mut sched = Scheduler::new(&lm, max_batch, 2);
        for id in 0..10 {
            sched.submit(GenRequest::top_k(
                id, vec![(id as u32) % 128, 9], 6, 5, 0.9, 1000 + id as u64));
        }
        sched.run().into_iter().map(|c| c.tokens).collect()
    };
    assert_eq!(run_topk(1), run_topk(8));
}

#[test]
fn ternary_and_dense_serve_comparable_distributions() {
    // Weight-identical twins: greedy streams may legitimately diverge
    // at near-ties, but the first decoded token (one step from a zero
    // state) must agree — a storage-format smoke check at the serving
    // level.
    let (tlm, dlm) = TernaryLm::synthetic_pair(dims(), 1, 46);
    let first = |out: Vec<Vec<u32>>| -> Vec<u32> {
        out.into_iter().map(|t| t[0]).collect()
    };
    let mut st = Scheduler::new(&tlm, 4, 1);
    let mut sd = Scheduler::new(&dlm, 4, 1);
    for r in request_set() {
        st.submit(r.clone());
        sd.submit(r);
    }
    let a = first(st.run().into_iter().map(|c| c.tokens).collect());
    let b = first(sd.run().into_iter().map(|c| c.tokens).collect());
    let agree = a.iter().zip(b.iter()).filter(|(x, y)| x == y).count();
    assert!(agree >= 10, "only {agree}/12 first tokens agree");
}
