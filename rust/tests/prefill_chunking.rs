//! Chunked-prefill equivalence + KV-capacity backpressure, end to end.
//!
//! The tentpole contract: feeding a lane's prompt in chunks (up to
//! `prefill_chunk` tokens per batched step, flattened into the
//! kernels' batch dimension) is an *operational* optimization, never a
//! semantic one. For every storage family (FloatLM, QuantLM-RTN,
//! QuantLM-GPTQ, TriLM) and both model kinds (decay-state `SpectraLm`,
//! paged-KV `AttnLm`), generated streams must be bitwise identical at
//! chunk sizes {1, 3, >= prompt_len}, and `ServeStats::prefill_tokens`
//! must account the same prompt-token total regardless of chunking.
//!
//! The foregrounded bugfix rides the same step path: exhausting the
//! KV page pool used to panic the whole server in `bind_and_begin`;
//! it now surfaces as per-lane rejection, which the scheduler turns
//! into requeue-with-pages-released. The overcommit tests here assert
//! the flipped polarity — every request completes, with the exact
//! streams an uncontended cache produces.

use spectra::serve::{FamilySpec, FinishReason, GenRequest, LatentAttnLm,
                     LatentLm, LmDims, QuantMethod, Scheduler};

fn dims() -> LmDims {
    LmDims { vocab: 96, hidden: 32, glu: 48, layers: 2 }
}

/// All four families of the acceptance bar, GPTQ included.
fn four_families() -> [FamilySpec; 4] {
    [
        FamilySpec::Float,
        FamilySpec::Quant { bits: 3, group: 128, method: QuantMethod::Rtn },
        FamilySpec::Quant { bits: 4, group: 128, method: QuantMethod::Gptq },
        FamilySpec::Ternary,
    ]
}

/// Prompts of 1..=7 tokens (so chunk 3 hits full, partial, and
/// single-token chunks) with heterogeneous budgets, greedy + top-k.
fn request_set() -> Vec<GenRequest> {
    (0..8).map(|id| {
        let len = 1 + (id * 3) % 7;
        let prompt: Vec<u32> =
            (0..len).map(|j| ((5 * id + 7 * j) % 96) as u32).collect();
        if id % 3 == 2 {
            GenRequest::top_k(id, prompt, 3 + id % 4, 4, 0.9, 77 + id as u64)
        } else {
            GenRequest::greedy(id, prompt, 3 + id % 4)
        }
    }).collect()
}

fn total_prompt_tokens() -> usize {
    request_set().iter().map(|r| r.prompt.len()).sum()
}

/// Chunk sizes of the acceptance bar: one-token, mid-prompt, and
/// >= every prompt length (7 is the longest prompt in `request_set`).
const CHUNKS: [usize; 3] = [1, 3, 7];

#[test]
fn decay_chunked_prefill_is_bitwise_invisible_across_families() {
    let latent = LatentLm::synthetic(dims(), 1, 0xC0FFE);
    for spec in four_families() {
        let model = latent.build(spec).unwrap();
        let run = |chunk: usize| {
            let mut sched =
                Scheduler::with_prefill_chunk(model.as_ref(), 4, 2, chunk);
            for r in request_set() {
                sched.submit(r);
            }
            let done = sched.run();
            let streams: Vec<Vec<u32>> =
                done.into_iter().map(|c| c.tokens).collect();
            (streams, sched.stats().prefill_tokens)
        };
        let (want, prefill_ref) = run(1);
        assert_eq!(want.len(), 8, "{}", spec.label());
        assert_eq!(prefill_ref, total_prompt_tokens(), "{}", spec.label());
        for chunk in CHUNKS {
            let (got, prefill) = run(chunk);
            assert_eq!(got, want,
                       "{}: decay streams diverge at prefill chunk {chunk}",
                       spec.label());
            assert_eq!(prefill, prefill_ref,
                       "{}: prefill_tokens accounting differs at chunk \
                        {chunk}", spec.label());
        }
    }
}

#[test]
fn attn_chunked_prefill_is_bitwise_invisible_across_families() {
    // The paged-KV model takes the true multi-token forward (one
    // kernel pass per projection over the flattened chunk, intra-chunk
    // causal attention): still bitwise identical to one-token prefill,
    // for all four families, with the cache roomy enough that
    // backpressure never triggers (that path has its own tests below).
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 0xC0FFF);
    for spec in four_families() {
        let model = latent.build(spec, 8, 16).unwrap();
        let run = |chunk: usize| {
            let mut sched =
                Scheduler::with_prefill_chunk(model.as_ref(), 4, 2, chunk);
            for r in request_set() {
                sched.submit(r);
            }
            let done = sched.run();
            let streams: Vec<Vec<u32>> =
                done.into_iter().map(|c| c.tokens).collect();
            let st = sched.stats().clone();
            (streams, st)
        };
        let (want, st_ref) = run(1);
        assert_eq!(want.len(), 8, "{}", spec.label());
        assert_eq!(st_ref.prefill_tokens, total_prompt_tokens(),
                   "{}", spec.label());
        assert_eq!(st_ref.requeued, 0, "{}: roomy cache must not \
                    backpressure", spec.label());
        for chunk in CHUNKS {
            let (got, st) = run(chunk);
            assert_eq!(got, want,
                       "{}: attn streams diverge at prefill chunk {chunk}",
                       spec.label());
            assert_eq!(st.prefill_tokens, st_ref.prefill_tokens,
                       "{}: prefill_tokens accounting differs at chunk \
                        {chunk}", spec.label());
        }
        // Chunking must actually compress time-to-first-token: at
        // chunk 7 every prompt lands in one step.
        let (_, st7) = run(7);
        assert!(st7.ttft_steps < st_ref.ttft_steps,
                "{}: chunked TTFT {} not better than one-token {}",
                spec.label(), st7.ttft_steps, st_ref.ttft_steps);
        assert!(st7.batch_steps < st_ref.batch_steps,
                "{}: chunking did not reduce batched steps", spec.label());
    }
}

#[test]
fn overcommitted_attn_completes_all_requests_at_every_chunk() {
    // THE regression (satellite bugfix): max_batch x context
    // overcommitted against a small page pool. Before the fix the
    // first lane that could not claim a page panicked the whole
    // server ("out of pages"); now refused lanes requeue with their
    // pages released and every request completes — at every prefill
    // chunk size, with streams identical to an uncontended run.
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 0xB00);
    // Uncontended reference: 8 lanes' worth of pages.
    let roomy = latent.build(FamilySpec::Ternary, 8, 16).unwrap();
    let mut sched = Scheduler::new(roomy.as_ref(), 8, 1);
    for r in request_set() {
        sched.submit(r);
    }
    let want: Vec<Vec<u32>> =
        sched.run().into_iter().map(|c| c.tokens).collect();

    // Overcommitted: pages for 2 lanes, 6 scheduler lanes, 8 requests
    // (max_batch x context = 6 x 16 tokens against a 2 x 16 pool).
    for chunk in CHUNKS {
        let tight = latent.build(FamilySpec::Ternary, 2, 16).unwrap();
        let mut sched =
            Scheduler::with_prefill_chunk(tight.as_ref(), 6, 1, chunk);
        for r in request_set() {
            sched.submit(r);
        }
        let done = sched.run();
        assert_eq!(done.len(), 8,
                   "chunk {chunk}: every request must complete");
        let got: Vec<Vec<u32>> =
            done.into_iter().map(|c| c.tokens).collect();
        assert_eq!(got, want,
                   "chunk {chunk}: backpressure changed a stream");
        assert!(sched.stats().requeued > 0,
                "chunk {chunk}: workload must actually overcommit");
        // Delivered-work accounting: abandoned attempts roll back, so
        // the prefill total equals the completed prompts' lengths even
        // under heavy requeueing — identical to the uncontended path.
        assert_eq!(sched.stats().prefill_tokens, total_prompt_tokens(),
                   "chunk {chunk}: requeues must not inflate \
                    prefill_tokens");
    }
}

#[test]
fn gptq_attn_overcommit_also_completes() {
    // The bugfix is family-blind: the GPTQ-calibrated attention model
    // under the same overcommit also completes every request.
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 0xB01);
    let spec = FamilySpec::Quant { bits: 4, group: 128,
                                   method: QuantMethod::Gptq };
    let tight = latent.build(spec, 2, 16).unwrap();
    let mut sched = Scheduler::with_prefill_chunk(tight.as_ref(), 5, 1, 3);
    for r in request_set() {
        sched.submit(r);
    }
    assert_eq!(sched.run().len(), 8);
}

#[test]
fn single_request_larger_than_the_whole_cache_error_completes() {
    // Backpressure cannot fix a sizing error: one request whose
    // context alone exceeds the entire page pool cannot make progress
    // (queueing it again would livelock). It used to panic the whole
    // scheduler; now it fails *that request* — an empty completion
    // with finish_reason kv_overflow, pages released, stats rolled
    // back — and the server keeps serving everyone else.
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 0xB02);
    let model = latent.build(FamilySpec::Float, 1, 16).unwrap();
    let mut sched = Scheduler::new(model.as_ref(), 1, 1);
    sched.submit(GenRequest::greedy(0, vec![1; 20], 8)); // needs > 16 slots
    let done = sched.run();
    assert_eq!(done.len(), 1, "the oversized request still completes");
    assert_eq!(done[0].id, 0);
    assert_eq!(done[0].finish_reason, FinishReason::KvOverflow);
    assert!(done[0].tokens.is_empty(),
            "an unservable request yields no tokens");
    assert_eq!(model.kv_pages_in_use(), 0,
               "the refused request must release every page");
    assert_eq!(sched.stats().prefill_tokens, 0,
               "prefill_tokens counts completed prompts only");
}

#[test]
fn kv_overflow_leaves_other_lanes_unharmed() {
    // The error-completion is per-request: an oversized request shares
    // the scheduler with a servable one, and the survivor's stream is
    // bitwise what it would have been alone.
    let latent = LatentAttnLm::synthetic(dims(), 4, 1, 0xB03);
    let clean = latent.build(FamilySpec::Float, 2, 16).unwrap();
    let mut sched = Scheduler::new(clean.as_ref(), 2, 1);
    sched.submit(GenRequest::greedy(0, vec![2, 3], 4));
    let alone: Vec<u32> = sched.run().remove(0).tokens;

    let model = latent.build(FamilySpec::Float, 2, 16).unwrap();
    let mut sched = Scheduler::new(model.as_ref(), 2, 1);
    sched.submit(GenRequest::greedy(0, vec![2, 3], 4));
    // 40 + 8 context tokens exceed even the whole 2-lane x 16-token
    // pool, so this lane can never be served, only error-completed.
    sched.submit(GenRequest::greedy(1, vec![1; 40], 8));
    let done = sched.run();
    assert_eq!(done.len(), 2);
    let by_id = |id: usize| done.iter().find(|c| c.id == id).unwrap();
    assert_eq!(by_id(1).finish_reason, FinishReason::KvOverflow);
    assert_eq!(by_id(0).finish_reason, FinishReason::Length);
    assert_eq!(by_id(0).tokens, alone,
               "the survivor's stream must be unchanged by the \
                overflowing neighbor");
    assert_eq!(model.kv_pages_in_use(), 0);
}
