//! Kernel-equivalence harness: every serving kernel against the
//! dequantized-f32 reference over a shape grid.
//!
//! Cross-checked kernels:
//!   matvec_dense            — dense f32 reference executor
//!   matvec_ternary_packed   — flat Packed2Bit scalar decode
//!   matmul_ternary_dense    — unpacked i8 matmul
//!   matmul_ternary_packed   — blocked/threaded PackedMatrix matmul
//!   matmul_quant_packed     — blocked/threaded k-bit QuantPacked matmul
//!
//! Ternary grid covers: cols not divisible by 4 (both the flat mid-byte
//! path and the row-aligned tail-byte path), rows = 1, single-scale vs
//! sharded scales, all-zero rows, shapes spanning multiple ROW_BLOCK x
//! COL_BLOCK_TRITS tiles, batch sizes {1, 3, 8} and thread counts
//! {1, 2, 5}; acceptance bar max |err| < 1e-4. The quant grid covers 3-
//! and 4-bit at group 128 over unaligned shapes (cols < group, ragged
//! final group, non-byte-aligned panel starts, tile-spanning) at the
//! same batch/thread grid; acceptance bar max |err| < 1e-3 plus bitwise
//! batch/thread invariance. All inputs come from seeded SplitMix64
//! streams.
//!
//! This suite checks the *scoped-thread* kernels against the dequant
//! reference; `tests/pool_equivalence.rs` then pins the pooled serving
//! path (`matmul_*_packed_into` on a persistent `WorkerPool`) bitwise
//! against these — so accuracy is proven once here and inherited by
//! the allocation-free hot path.

use spectra::linear::{matmul_quant_packed, QuantPacked};
use spectra::quant::QuantTensor;
use spectra::runtime::HostTensor;
use spectra::ternary::matmul::{COL_BLOCK_TRITS, ROW_BLOCK};
use spectra::ternary::{matmul_dense, matmul_ternary_dense,
                       matmul_ternary_packed, matvec_dense,
                       matvec_ternary_packed, Packed2Bit, PackedMatrix,
                       TernaryTensor};

const TOL: f32 = 1e-4;
const QTOL: f32 = 1e-3;

/// (rows, cols) grid: edge and tile-spanning shapes.
fn shape_grid() -> Vec<(usize, usize)> {
    vec![
        (1, 4),                              // single row, aligned
        (1, 7),                              // single row, tail bytes
        (2, 8),
        (3, 5),                              // both dims odd/unaligned
        (7, 10),
        (8, 12),
        (16, 16),
        (32, 20),
        (33, 64),                            // odd, block-unaligned
        (ROW_BLOCK + 9, COL_BLOCK_TRITS + 37), // spans tiles + tail
        (64, 48),
    ]
}

/// Scale-shard counts valid for `rows`: single scale plus every
/// sharding the suite's mp grid would produce.
fn mp_grid(rows: usize) -> Vec<usize> {
    [1usize, 2, 3, 4].into_iter()
        .filter(|&mp| mp <= rows && rows % mp == 0)
        .collect()
}

fn max_abs_err(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn check_all_kernels(t: &TernaryTensor, seed: u64, label: &str) {
    let dq = t.dequant();
    let flat = Packed2Bit::pack(&t.states);
    let pm = PackedMatrix::from_ternary(t);

    // Scalar decode path vs dense reference.
    let x1 = HostTensor::randn(vec![1, t.cols], 1.0, seed ^ 1);
    let want_v = matvec_dense(&dq, &x1.data);
    let got_v = matvec_ternary_packed(&flat, t.rows, t.cols, &t.scales,
                                      &x1.data);
    assert!(max_abs_err(&got_v, &want_v) < TOL, "{label}: matvec packed");

    // Batched paths at several batch sizes and thread counts.
    for m in [1usize, 3, 8] {
        let x = HostTensor::randn(vec![m, t.cols], 1.0, seed ^ (m as u64) << 8);
        let want = matmul_dense(&x, &dq);

        let got_dense_t = matmul_ternary_dense(&x, t);
        assert!(max_abs_err(&got_dense_t.data, &want.data) < TOL,
                "{label} m={m}: matmul_ternary_dense");

        for threads in [1usize, 2, 5] {
            let got = matmul_ternary_packed(&x, &pm, threads);
            assert_eq!(got.shape, vec![m, t.rows]);
            let err = max_abs_err(&got.data, &want.data);
            assert!(err < TOL,
                    "{label} m={m} threads={threads}: \
                     matmul_ternary_packed err {err}");
        }
    }

    // Kernel-generation consistency: batched kernel at m=1 vs matvec.
    let got_m1 = matmul_ternary_packed(&x1, &pm, 1);
    assert!(max_abs_err(&got_m1.data, &got_v) < TOL,
            "{label}: matmul(m=1) vs matvec disagree");
}

#[test]
fn equivalence_over_shape_and_scale_grid() {
    let mut seed = 0xA11CE;
    for (rows, cols) in shape_grid() {
        for mp in mp_grid(rows) {
            seed += 1;
            let w = HostTensor::randn(vec![rows, cols], 0.05, seed);
            let t = TernaryTensor::from_latent(&w, mp);
            assert_eq!(t.scales.len(), mp);
            check_all_kernels(&t, seed, &format!("{rows}x{cols} mp={mp}"));
        }
    }
}

#[test]
fn equivalence_with_all_zero_rows() {
    // Every other row all-zero: the sparsity skip must not desync
    // row/scale bookkeeping, and zero rows must emit exact zeros.
    for (rows, cols) in [(4usize, 8usize), (6, 10), (33, 20)] {
        let mut states = vec![0i8; rows * cols];
        for r in 0..rows {
            if r % 2 == 0 {
                for c in 0..cols {
                    states[r * cols + c] = match (r + c) % 3 {
                        0 => 1,
                        1 => -1,
                        _ => 0,
                    };
                }
            }
        }
        let t = TernaryTensor {
            rows, cols, states, scales: vec![0.7],
        };
        check_all_kernels(&t, 0xDEAD ^ rows as u64, &format!(
            "zero-rows {rows}x{cols}"));
        let x = HostTensor::randn(vec![2, cols], 1.0, 5);
        let y = matmul_ternary_packed(&x, &PackedMatrix::from_ternary(&t), 2);
        for r in (1..rows).step_by(2) {
            for mi in 0..2 {
                assert_eq!(y.at2(mi, r), 0.0, "zero row {r} leaked");
            }
        }
    }
}

#[test]
fn equivalence_with_extreme_scales() {
    // Tiny and large shard scales through the full kernel stack.
    let rows = 8;
    let cols = 12;
    let w = HostTensor::randn(vec![rows, cols], 0.05, 77);
    let mut t = TernaryTensor::from_latent(&w, 2);
    t.scales = vec![1e-4, 40.0];
    // Relative check at large scale: compare against dequant reference.
    let dq = t.dequant();
    let x = HostTensor::randn(vec![3, cols], 1.0, 78);
    let want = matmul_dense(&x, &dq);
    let got = matmul_ternary_packed(&x, &PackedMatrix::from_ternary(&t), 2);
    for (a, b) in got.data.iter().zip(want.data.iter()) {
        let tol = TOL * b.abs().max(1.0);
        assert!((a - b).abs() < tol, "{a} vs {b}");
    }
}

/// Quant shapes, all "unaligned" somehow: cols < group (single ragged
/// group), ragged final group, cols not a multiple of 8 values (rows
/// start byte-aligned but panels decode from mid-byte bit offsets),
/// and a ROW_BLOCK/COL_BLOCK tile-spanning shape.
fn quant_shape_grid() -> Vec<(usize, usize)> {
    vec![
        (1, 7),                                // single row, sub-group
        (8, 100),                              // cols < group
        (33, 130),                             // ragged final group
        (64, 131),                             // ragged + odd cols
        (ROW_BLOCK + 9, COL_BLOCK_TRITS + 37), // spans tiles + ragged
    ]
}

#[test]
fn quant_kernel_matches_dequant_reference() {
    // 3- and 4-bit at group 128 (the paper's QuantLM configs) over the
    // unaligned shape grid: the packed-bitstream kernel must land
    // within 1e-3 of matmul against the dequantized f32 weights.
    let mut seed = 0xBEE5u64;
    for bits in [3u32, 4] {
        for (rows, cols) in quant_shape_grid() {
            seed += 1;
            let w = HostTensor::randn(vec![rows, cols], 0.05, seed);
            let qt = QuantTensor::quantize_rtn(&w, bits, 128);
            let qp = QuantPacked::from_quant(&qt);
            let dq = qt.dequant();
            for m in [1usize, 3, 8] {
                let x = HostTensor::randn(vec![m, cols], 1.0,
                                          seed ^ (m as u64) << 8);
                let want = matmul_dense(&x, &dq);
                for threads in [1usize, 2, 5] {
                    let got = matmul_quant_packed(&x, &qp, threads);
                    assert_eq!(got.shape, vec![m, rows]);
                    let err = max_abs_err(&got.data, &want.data);
                    assert!(err < QTOL,
                            "{rows}x{cols} bits={bits} m={m} \
                             threads={threads}: err {err}");
                }
            }
        }
    }
}

#[test]
fn quant_kernel_batch_and_thread_invariance_is_bitwise() {
    // Same contract as the ternary kernel: a lane's result is bitwise
    // identical at any batch size and thread count — what lets the
    // scheduler serve QuantLMs deterministically.
    for bits in [3u32, 4] {
        let w = HostTensor::randn(vec![48, COL_BLOCK_TRITS + 11], 0.05,
                                  70 + bits as u64);
        let qp = QuantPacked::from_quant(
            &QuantTensor::quantize_rtn(&w, bits, 128));
        let xb = HostTensor::randn(vec![8, qp.cols], 1.0, 80 + bits as u64);
        let reference = matmul_quant_packed(&xb, &qp, 1);
        for threads in [2usize, 3, 8] {
            let got = matmul_quant_packed(&xb, &qp, threads);
            assert_eq!(got.data, reference.data,
                       "bits={bits} threads={threads}");
        }
        for mi in 0..8 {
            let x1 = HostTensor::stack_rows(&[xb.row(mi)]);
            let solo = matmul_quant_packed(&x1, &qp, 4);
            assert_eq!(solo.data, reference.row(mi),
                       "bits={bits} lane {mi}");
        }
    }
}

#[test]
fn batch_and_thread_invariance_is_bitwise() {
    // Stronger than the tolerance harness: each lane's result is
    // bitwise identical across batch sizes and thread counts — the
    // property the serve scheduler's determinism rests on.
    let w = HostTensor::randn(vec![48, COL_BLOCK_TRITS + 11], 0.05, 91);
    let t = TernaryTensor::from_latent(&w, 2);
    let pm = PackedMatrix::from_ternary(&t);
    let xb = HostTensor::randn(vec![8, t.cols], 1.0, 92);
    let reference = matmul_ternary_packed(&xb, &pm, 1);
    for threads in [2usize, 3, 8] {
        let got = matmul_ternary_packed(&xb, &pm, threads);
        assert_eq!(got.data, reference.data, "threads={threads}");
    }
    for mi in 0..8 {
        let x1 = HostTensor::stack_rows(&[xb.row(mi)]);
        let solo = matmul_ternary_packed(&x1, &pm, 4);
        assert_eq!(solo.data, reference.row(mi), "lane {mi}");
    }
}
