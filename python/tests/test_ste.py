"""Straight-through-estimator gradients (Table 1 backward-pass column).

The custom_vjp of every quantized linear must produce exactly
dL/dX = dL/dY @ W~ and dL/dW = dL/dY^T @ X, where W~ is the dequantized
(ternarized / binarized) weight — NOT the latent weight.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import binary, bitnet, ref, ternary

dims = st.sampled_from([8, 16, 32, 64])
seeds = st.integers(0, 2**31 - 1)


def _check_ste(linear_fn, wtilde_fn, m, n, k, seed, x_transform=None):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    dy = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))

    def scalar_loss(x, w):
        return jnp.sum(linear_fn(x, w, 1) * dy)

    dx, dw = jax.grad(scalar_loss, argnums=(0, 1))(x, w)
    w_t = wtilde_fn(w)
    x_eff = x_transform(x) if x_transform else x
    np.testing.assert_allclose(dx, dy @ w_t, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(dw, dy.T @ x_eff, atol=1e-3, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(m=dims, n=dims, k=dims, seed=seeds)
def test_ternary_ste(m, n, k, seed):
    def wtilde(w):
        return ref.ternary_dequant(*ref.ternarize(w, 1))
    _check_ste(ternary.ternary_linear, wtilde, m, n, k, seed)


@settings(max_examples=10, deadline=None)
@given(m=dims, n=dims, k=dims, seed=seeds)
def test_binary_ste(m, n, k, seed):
    def wtilde(w):
        w_hat, alpha = ref.binarize(w, 1)
        return alpha[0] * w_hat
    _check_ste(binary.binary_linear, wtilde, m, n, k, seed)


@settings(max_examples=10, deadline=None)
@given(m=dims, n=dims, k=dims, seed=seeds)
def test_bitnet_ste(m, n, k, seed):
    def wtilde(w):
        return ref.ternary_dequant(*ref.ternarize(w, 1))

    def xq(x):
        return ref.absmax_quant_act(ref.parameterless_rmsnorm(x))

    _check_ste(bitnet.bitnet_linear, wtilde, m, n, k, seed, x_transform=xq)


def test_ste_grad_flows_through_zero_states():
    """Latent weights whose ternary state is 0 still receive gradient —
    the mechanism that lets states flip as updates accumulate (§3.1)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    w = jnp.asarray(0.01 * rng.normal(size=(16, 16)).astype(np.float32))
    w_hat, _ = ref.ternarize(w, 1)
    # ensure some zero states exist
    assert float(jnp.mean(w_hat == 0)) > 0

    g = jax.grad(lambda w: jnp.sum(ternary.ternary_linear(x, w, 1) ** 2))(w)
    zero_mask = np.asarray(w_hat == 0)
    assert np.abs(np.asarray(g)[zero_mask]).max() > 0
