"""L2 model tests: shapes, training dynamics, capture graph, families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

TINY = M.ModelConfig(name="tiny", vocab=64, hidden=32, glu=96, heads=2,
                     layers=2, seq=16, mp=2, family="ternary")


def _tokens(rng, cfg, batch, extra=1):
    return jnp.asarray(
        rng.integers(0, cfg.vocab, size=(batch, cfg.seq + extra)).astype(np.int32))


@pytest.mark.parametrize("family", M.FAMILIES)
def test_forward_shapes(family):
    cfg = M.ModelConfig(name="t", vocab=64, hidden=32, glu=96, heads=2,
                        layers=2, seq=16, mp=1, family=family)
    params = M.init_params(cfg, 0)
    rng = np.random.default_rng(0)
    toks = _tokens(rng, cfg, 3, extra=0)
    logits = M.forward(cfg, params, toks)
    assert logits.shape == (3, cfg.seq, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_param_specs_order_is_deterministic():
    s1 = M.param_specs(TINY)
    s2 = M.param_specs(TINY)
    assert s1 == s2
    names = [n for n, _ in s1]
    assert names[0] == "embed" and names[-1] == "lm_head"
    assert names.count("final_norm") == 1
    # 7 linears + 2 norms per layer
    assert len(names) == 2 + 1 + TINY.layers * 9


def test_initial_loss_near_uniform():
    """Untrained model CE should sit near log(vocab)."""
    params = M.init_params(TINY, 0)
    rng = np.random.default_rng(1)
    loss = float(M.loss_fn(TINY, params, _tokens(rng, TINY, 4)))
    assert abs(loss - np.log(TINY.vocab)) < 0.5


@pytest.mark.parametrize("family", ["float", "ternary"])
def test_train_step_reduces_loss_on_overfit_batch(family):
    cfg = M.ModelConfig(name="t", vocab=64, hidden=32, glu=96, heads=2,
                        layers=2, seq=16, mp=1, family=family)
    params = M.init_params(cfg, 0)
    m = {k: jnp.zeros_like(p) for k, p in params.items()}
    v = {k: jnp.zeros_like(p) for k, p in params.items()}
    rng = np.random.default_rng(2)
    toks = _tokens(rng, cfg, 4)
    step = jnp.array(0.0)
    lr = jnp.array(3e-3 if family == "ternary" else 1e-3)

    fn = jax.jit(lambda p, m, v, s: M.train_step(
        cfg, False, p, m, v, s, toks, lr, jnp.array(0.1), jnp.array(1.0)))
    losses = []
    for _ in range(12):
        params, m, v, step, loss, gnorm, finite = fn(params, m, v, step)
        assert float(finite) == 1.0
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3, losses


def test_train_step_skips_update_on_overflow():
    """A loss scale large enough to overflow f16 grads must leave the
    parameters untouched and report finite=0 (Table 5 mechanism)."""
    cfg = TINY.with_family("float")
    params = M.init_params(cfg, 0)
    m = {k: jnp.zeros_like(p) for k, p in params.items()}
    v = {k: jnp.zeros_like(p) for k, p in params.items()}
    rng = np.random.default_rng(3)
    toks = _tokens(rng, cfg, 2)
    huge = jnp.array(1e30)
    p2, m2, v2, step2, loss, gnorm, finite = M.train_step(
        cfg, True, params, m, v, jnp.array(5.0), toks,
        jnp.array(1e-3), jnp.array(0.1), huge)
    assert float(finite) == 0.0
    assert float(step2) == 5.0
    for k in params:
        np.testing.assert_array_equal(np.asarray(p2[k]), np.asarray(params[k]))


def test_fp16_sim_matches_f32_at_moderate_scale():
    """With a sane loss scale the fp16-grad path stays finite and tracks
    the f32 path closely."""
    cfg = TINY.with_family("float")
    params = M.init_params(cfg, 0)
    m = {k: jnp.zeros_like(p) for k, p in params.items()}
    v = {k: jnp.zeros_like(p) for k, p in params.items()}
    rng = np.random.default_rng(4)
    toks = _tokens(rng, cfg, 2)
    args = (params, m, v, jnp.array(0.0), toks, jnp.array(1e-3),
            jnp.array(0.1), jnp.array(128.0))
    out16 = M.train_step(cfg, True, *args)
    out32 = M.train_step(cfg, False, *args)
    assert float(out16[6]) == 1.0
    np.testing.assert_allclose(float(out16[4]), float(out32[4]), rtol=1e-3)
    for k in params:
        np.testing.assert_allclose(np.asarray(out16[0][k]),
                                   np.asarray(out32[0][k]), atol=1e-4)


def test_capture_linear_inputs_shapes_and_order():
    cfg = TINY.with_family("float")
    params = M.init_params(cfg, 0)
    rng = np.random.default_rng(5)
    toks = _tokens(rng, cfg, 2, extra=0)
    caps = M.capture_linear_inputs(cfg, params, toks)
    assert len(caps) == cfg.layers * M.CAPTURES_PER_LAYER
    rows = 2 * cfg.seq
    for l in range(cfg.layers):
        assert caps[4 * l + 0].shape == (rows, cfg.hidden)   # qkv input
        assert caps[4 * l + 1].shape == (rows, cfg.hidden)   # o input
        assert caps[4 * l + 2].shape == (rows, cfg.hidden)   # gate/up input
        assert caps[4 * l + 3].shape == (rows, cfg.glu)      # down input


def test_capture_forward_consistent_with_eval():
    """Replaying the captured down-proj input through the weights must
    reproduce the float forward's MLP output contribution."""
    cfg = TINY.with_family("float")
    params = M.init_params(cfg, 0)
    rng = np.random.default_rng(6)
    toks = _tokens(rng, cfg, 2, extra=0)
    caps = M.capture_linear_inputs(cfg, params, toks)
    # check q projection from captured input matches a manual projection
    q_manual = caps[0] @ params["l0.attn_q"].T
    assert q_manual.shape == (2 * cfg.seq, cfg.hidden)
    assert bool(jnp.all(jnp.isfinite(q_manual)))


def test_suite_configs_param_counts_are_spread():
    counts = [M.n_params(M.suite_config(s)) for s in M.SUITE]
    assert counts == sorted(counts)
    assert counts[-1] / counts[0] > 20  # suite spans >1 order of magnitude


def test_token_logprobs_are_logprobs():
    cfg = TINY.with_family("float")
    params = M.init_params(cfg, 0)
    rng = np.random.default_rng(7)
    lp = M.token_logprobs(cfg, params, _tokens(rng, cfg, 2))
    assert lp.shape == (2, cfg.seq)
    assert bool(jnp.all(lp <= 0))
