"""AOT lowering tests: HLO text round-trips and manifests are coherent."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

from compile import aot
from compile import model as M

TINY = M.ModelConfig(name="tiny_ternary", vocab=64, hidden=32, glu=96,
                     heads=2, layers=2, seq=16, mp=1, family="ternary")


def test_train_graph_lowers_to_hlo_text():
    text = aot.to_hlo_text(aot.lower_train(TINY, batch=2, fp16_grads=False))
    assert "ENTRY" in text
    assert "HloModule" in text


def test_eval_graph_lowers_to_hlo_text():
    text = aot.to_hlo_text(aot.lower_eval(TINY, batch=2))
    assert "ENTRY" in text


def test_graph_io_spec_counts():
    cfg = M.suite_config("160k", "ternary")
    P = len(M.param_specs(cfg))
    ins, outs = aot.graph_io_spec(cfg, "train")
    assert len(ins) == 3 * P + 5       # params,m,v + step,tokens,lr,wd,scale
    assert len(outs) == 3 * P + 4      # params,m,v + step,loss,gnorm,finite
    ins, outs = aot.graph_io_spec(cfg, "eval")
    assert len(ins) == P + 1 and len(outs) == 1
    ins, outs = aot.graph_io_spec(cfg, "capture")
    assert len(outs) == cfg.layers * M.CAPTURES_PER_LAYER


def test_build_plan_respects_paper_scope():
    plan = aot.build_plan(list(M.SUITE), ["float", "ternary", "binary", "bitnet"])
    names = {(c.name, g) for c, g, _ in plan}
    # BiLM only at its three sizes (App. B)
    assert ("160k_binary", "train") in names
    assert ("430k_binary", "train") not in names
    # BitNet replication at one size (§A.6)
    assert sum(1 for (n, g) in names if n.endswith("_bitnet") and g == "train") == 1
    # capture graphs only for FloatLM
    assert all(n.endswith("_float") for (n, g) in names if g == "capture")
    # fp16 variants only at the loss-scaling study sizes
    fp16 = {n for (n, g) in names if g == "train_fp16"}
    assert fp16 == {f"{s}_{f}" for s in aot.FP16_SIZES for f in ("float", "ternary")}


@pytest.mark.slow
def test_aot_cli_writes_manifest():
    with tempfile.TemporaryDirectory() as td:
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", td,
             "--sizes", "160k", "--families", "ternary"],
            check=True, cwd=os.path.dirname(os.path.dirname(__file__)))
        with open(os.path.join(td, "manifest.json")) as f:
            man = json.load(f)
        entry = man["models"]["160k_ternary"]
        assert entry["n_params"] > 150_000
        assert set(entry["graphs"]) == {"train", "eval", "next_logits",
                                        "train_fp16"}
        for g in entry["graphs"].values():
            assert os.path.exists(os.path.join(td, g["file"]))
