"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes (and the model-parallel degree / group size /
bit width parameters) and asserts allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import binary, bitnet, qlinear, ref, ternary

ATOL = 2e-4
RTOL = 2e-4


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


dims = st.sampled_from([8, 16, 32, 48, 64, 96, 128, 160, 256])
mps = st.sampled_from([1, 2, 4])
seeds = st.integers(0, 2**31 - 1)


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, k=dims, mp=mps, seed=seeds)
def test_ternary_matmul_matches_ref(m, n, k, mp, seed):
    if n % mp:
        mp = 1
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, n, k)
    got = ternary.ternary_linear(x, w, mp)
    want = ref.ternary_linear(x, w, mp)
    np.testing.assert_allclose(got, want, atol=ATOL * k, rtol=RTOL)


@settings(max_examples=15, deadline=None)
@given(m=dims, n=dims, k=dims, mp=mps, seed=seeds)
def test_ternary_infer_matches_train_path(m, n, k, mp, seed):
    """Inference with cached (w_hat, gamma) == training on-the-fly path."""
    if n % mp:
        mp = 1
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, n, k)
    w_hat, _ = ref.ternarize(w, mp)
    got = ternary.ternary_matmul_infer(x, w_hat.astype(jnp.int8),
                                       ternary.gamma_rows(w, mp))
    want = ternary.ternary_linear(x, w, mp)
    np.testing.assert_allclose(got, want, atol=ATOL * k, rtol=RTOL)


@settings(max_examples=20, deadline=None)
@given(m=dims, n=dims, k=dims, mp=mps, seed=seeds)
def test_binary_matmul_matches_ref(m, n, k, mp, seed):
    if n % mp:
        mp = 1
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, n, k)
    np.testing.assert_allclose(binary.binary_linear(x, w, mp),
                               ref.binary_linear(x, w, mp),
                               atol=ATOL * k, rtol=RTOL)


@settings(max_examples=20, deadline=None)
@given(m=dims, n=dims, k=dims, seed=seeds)
def test_bitnet_matmul_matches_ref(m, n, k, seed):
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, n, k)
    np.testing.assert_allclose(bitnet.bitnet_linear(x, w, 1),
                               ref.bitnet_linear(x, w, 1),
                               atol=ATOL * k, rtol=RTOL)


@settings(max_examples=20, deadline=None)
@given(m=dims, n=dims, k=st.sampled_from([32, 64, 128, 256]),
       bits=st.sampled_from([3, 4, 6, 8]),
       group=st.sampled_from([16, 32, 64, 128]), seed=seeds)
def test_quant_matmul_matches_ref(m, n, k, bits, group, seed):
    group = min(group, k)
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, n, k)
    q, s = ref.group_quant(w, bits, group)
    got = qlinear.quant_matmul(x, q.reshape(n, k).astype(jnp.int8), s, group)
    want = ref.quant_linear(x, q, s)
    np.testing.assert_allclose(got, want, atol=ATOL * k, rtol=RTOL)


# ---------------------------------------------------------------------------
# Quantizer semantics (Table 1 invariants)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(n=dims, k=dims, mp=mps, seed=seeds)
def test_ternarize_states_and_scales(n, k, mp, seed):
    if n % mp:
        mp = 1
    rng = np.random.default_rng(seed)
    w = rand(rng, n, k)
    w_hat, gamma = ref.ternarize(w, mp)
    states = np.unique(np.asarray(w_hat))
    assert set(states).issubset({-1.0, 0.0, 1.0})
    assert gamma.shape == (mp,)
    assert np.all(np.asarray(gamma) > 0)
    # gamma is the absmean of the shard (+eps)
    shard = np.asarray(w).reshape(mp, n // mp, k)
    np.testing.assert_allclose(gamma, np.abs(shard).mean(axis=(1, 2)) + ref.EPS,
                               rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(n=dims, k=dims, seed=seeds)
def test_binarize_states(n, k, seed):
    rng = np.random.default_rng(seed)
    w = rand(rng, n, k)
    w_hat, alpha = ref.binarize(w, 1)
    assert set(np.unique(np.asarray(w_hat))).issubset({-1.0, 1.0})
    assert float(alpha[0]) > 0


@settings(max_examples=30, deadline=None)
@given(n=dims, k=st.sampled_from([32, 64, 128]),
       bits=st.sampled_from([3, 4, 6, 8]), seed=seeds)
def test_group_quant_roundtrip_error_bound(n, k, bits, seed):
    """Symmetric group quant error is bounded by half a quantization step."""
    rng = np.random.default_rng(seed)
    w = rand(rng, n, k)
    q, s = ref.group_quant(w, bits, 32)
    back = ref.group_dequant(q, s)
    step = np.asarray(s)[..., None] * np.ones((1, 1, 32))
    err = np.abs(np.asarray(back).reshape(n, k // 32, 32) -
                 np.asarray(w).reshape(n, k // 32, 32))
    assert np.all(err <= 0.5 * step + 1e-6)


def test_higher_bits_lower_error():
    """More bits => monotonically smaller reconstruction error (§4.2)."""
    rng = np.random.default_rng(7)
    w = rand(rng, 64, 128)
    errs = []
    for bits in (3, 4, 6, 8):
        q, s = ref.group_quant(w, bits, 128)
        errs.append(float(jnp.mean((ref.group_dequant(q, s) - w) ** 2)))
    assert errs == sorted(errs, reverse=True)


def test_activation_quant_is_idempotent():
    rng = np.random.default_rng(3)
    x = rand(rng, 16, 64)
    q1 = ref.absmax_quant_act(x)
    q2 = ref.absmax_quant_act(q1)
    np.testing.assert_allclose(q1, q2, atol=1e-5)


@pytest.mark.parametrize("mp", [1, 2, 3, 6])
def test_mp_scale_artifact_count(mp):
    """§A.5: model parallelism adds exactly mp scale values per matrix."""
    rng = np.random.default_rng(0)
    w = rand(rng, 96, 64)
    _, gamma = ref.ternarize(w, mp)
    assert gamma.shape == (mp,)
