"""L2: the Spectra model zoo — LLaMa-style transformers in JAX.

One architecture (§3.1 / §4.2), four linear-layer families:

- ``float``   — FloatLM: plain FP matmuls (f32 here; the paper's FP16
                semantics are reproduced by the fp16-grad-simulation
                train-step variant and by the bit-accounting in Rust).
- ``ternary`` — TriLM: on-the-fly absmean ternarization with per-shard
                scales and STE gradients (Pallas kernel, kernels/ternary).
- ``binary``  — BiLM: centered-sign binarization (kernels/binary).
- ``bitnet``  — BitNet b1.58 replication: parameterless pre-norm +
                8-bit act quant + ternary weights (kernels/bitnet).

Architecture: RMSNorm (with scale), SwiGLU gated MLP, RoPE, multi-headed
attention, no bias terms, untied embedding / LM head. Embedding and LM
head are always full-precision (§A.1: only linear-layer weights are
quantized).

Everything here is build-time Python: the train/eval/capture graphs are
AOT-lowered to HLO text by aot.py and executed from Rust. Python never
runs on the request path.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp

from .kernels.binary import binary_linear
from .kernels.bitnet import bitnet_linear
from .kernels.ternary import ternary_linear

FAMILIES = ("float", "ternary", "binary", "bitnet")

ADAM_B1 = 0.9
ADAM_B2 = 0.95  # paper §A.4: Adam betas (0.9, 0.95)
ADAM_EPS = 1e-8
NORM_EPS = 1e-5


@dataclass(frozen=True)
class ModelConfig:
    """One Spectra suite entry (Table 3 analog; see DESIGN.md scale map)."""

    name: str
    vocab: int
    hidden: int
    glu: int
    heads: int
    layers: int
    seq: int
    mp: int = 1          # model-parallel degree -> per-shard scale count
    family: str = "float"

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        assert self.hidden % self.heads == 0
        assert self.hidden % self.mp == 0 and self.glu % self.mp == 0

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    def with_family(self, family: str) -> "ModelConfig":
        return replace(self, family=family)


# The repro suite grid (DESIGN.md "Scale mapping"). Vocab matches the
# Rust BPE tokenizer; seq = 128 everywhere; mp mirrors the paper's
# Table 3 pattern of growing model parallelism with scale.
SUITE: dict[str, dict[str, Any]] = {
    "160k": dict(hidden=64, glu=160, heads=1, layers=2, mp=1),
    "430k": dict(hidden=96, glu=256, heads=2, layers=3, mp=1),
    "930k": dict(hidden=128, glu=352, heads=2, layers=4, mp=1),
    "2.8m": dict(hidden=192, glu=512, heads=3, layers=6, mp=2),
    "6.7m": dict(hidden=256, glu=704, heads=4, layers=8, mp=2),
    "15m": dict(hidden=384, glu=1056, heads=6, layers=8, mp=3),
}


def suite_config(size: str, family: str = "float", vocab: int = 512,
                 seq: int = 128) -> ModelConfig:
    spec = SUITE[size]
    return ModelConfig(name=f"{size}_{family}", vocab=vocab, seq=seq,
                       family=family, **spec)


# ---------------------------------------------------------------------------
# Parameters. Flat, ordered dict: name -> array. The ordering is the
# AOT calling convention shared with Rust (manifest.json).
# ---------------------------------------------------------------------------

# The seven quantizable linear weights of each transformer layer (§A.1).
LINEAR_NAMES = ("attn_q", "attn_k", "attn_v", "attn_o",
                "mlp_gate", "mlp_up", "mlp_down")


def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list — the flat calling convention."""
    specs: list[tuple[str, tuple[int, ...]]] = [("embed", (cfg.vocab, cfg.hidden))]
    h, g = cfg.hidden, cfg.glu
    shapes = dict(attn_q=(h, h), attn_k=(h, h), attn_v=(h, h), attn_o=(h, h),
                  mlp_gate=(g, h), mlp_up=(g, h), mlp_down=(h, g))
    for l in range(cfg.layers):
        specs.append((f"l{l}.attn_norm", (h,)))
        for n in ("attn_q", "attn_k", "attn_v", "attn_o"):
            specs.append((f"l{l}.{n}", shapes[n]))
        specs.append((f"l{l}.mlp_norm", (h,)))
        for n in ("mlp_gate", "mlp_up", "mlp_down"):
            specs.append((f"l{l}.{n}", shapes[n]))
    specs.append(("final_norm", (h,)))
    specs.append(("lm_head", (cfg.vocab, h)))
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, jnp.ndarray]:
    """GPT-NeoX-style small init; residual-out projections down-scaled."""
    key = jax.random.PRNGKey(seed)
    params: dict[str, jnp.ndarray] = {}
    resid_scale = 1.0 / math.sqrt(2.0 * cfg.layers)
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            std = 0.02
            if name.endswith(("attn_o", "mlp_down")):
                std *= resid_scale
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def n_params(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_specs(cfg))


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _linear(cfg: ModelConfig, x2d: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Family dispatch for the quantizable linears. x2d: (tokens, in)."""
    if cfg.family == "float":
        return x2d @ w.T
    if cfg.family == "ternary":
        return ternary_linear(x2d, w, cfg.mp)
    if cfg.family == "binary":
        return binary_linear(x2d, w, cfg.mp)
    if cfg.family == "bitnet":
        return bitnet_linear(x2d, w, cfg.mp)
    raise ValueError(cfg.family)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return scale * x * (1.0 / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True)
                                       + NORM_EPS))


def rope(x: jnp.ndarray) -> jnp.ndarray:
    """Rotary position embedding over (B, S, H, D)."""
    b, s, h, d = x.shape
    half = d // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    t = jnp.arange(s, dtype=jnp.float32)
    ang = t[:, None] * freqs[None, :]                    # (S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    rot1 = x1 * cos[None, :, None, :] - x2 * sin[None, :, None, :]
    rot2 = x2 * cos[None, :, None, :] + x1 * sin[None, :, None, :]
    return jnp.concatenate([rot1, rot2], axis=-1)


def _attention(cfg: ModelConfig, params, l: int, x: jnp.ndarray) -> jnp.ndarray:
    b, s, h = x.shape
    xn = rmsnorm(x, params[f"l{l}.attn_norm"])
    x2 = xn.reshape(b * s, h)
    q = _linear(cfg, x2, params[f"l{l}.attn_q"]).reshape(b, s, cfg.heads, cfg.head_dim)
    k = _linear(cfg, x2, params[f"l{l}.attn_k"]).reshape(b, s, cfg.heads, cfg.head_dim)
    v = _linear(cfg, x2, params[f"l{l}.attn_v"]).reshape(b, s, cfg.heads, cfg.head_dim)
    q, k = rope(q), rope(k)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(cfg.head_dim)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b * s, h)
    return x + _linear(cfg, ctx, params[f"l{l}.attn_o"]).reshape(b, s, h)


def _mlp(cfg: ModelConfig, params, l: int, x: jnp.ndarray) -> jnp.ndarray:
    b, s, h = x.shape
    xn = rmsnorm(x, params[f"l{l}.mlp_norm"]).reshape(b * s, h)
    gate = _linear(cfg, xn, params[f"l{l}.mlp_gate"])
    up = _linear(cfg, xn, params[f"l{l}.mlp_up"])
    y = _linear(cfg, jax.nn.silu(gate) * up, params[f"l{l}.mlp_down"])
    return x + y.reshape(b, s, h)


def forward(cfg: ModelConfig, params, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens (B, S) int32 -> logits (B, S, vocab) f32."""
    x = params["embed"][tokens]
    for l in range(cfg.layers):
        x = _attention(cfg, params, l, x)
        x = _mlp(cfg, params, l, x)
    x = rmsnorm(x, params["final_norm"])
    return x @ params["lm_head"].T


def token_logprobs(cfg: ModelConfig, params, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens (B, S+1) -> log p(tokens[:,1:]) at each position, (B, S)."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]


def loss_fn(cfg: ModelConfig, params, tokens: jnp.ndarray) -> jnp.ndarray:
    return -jnp.mean(token_logprobs(cfg, params, tokens))


# ---------------------------------------------------------------------------
# AdamW train step (graph executed from Rust)
# ---------------------------------------------------------------------------

def _decay_mask(name: str) -> bool:
    """Weight decay applies to matrices only, not norms (standard)."""
    return not name.endswith("norm")


def train_step(cfg: ModelConfig, fp16_grads: bool, params, m, v, step,
               tokens, lr, wd, loss_scale):
    """One AdamW step with dynamic-loss-scaling support (§A.3, Table 5).

    step/lr/wd/loss_scale are f32 scalars supplied by the Rust
    coordinator (which owns the schedule and the loss-scale state
    machine). Returns (params', m', v', loss, grad_norm, grads_finite).

    With ``fp16_grads``, the scaled gradients are round-tripped through
    f16 before unscaling — reproducing the overflow behaviour of V100
    mixed-precision training that Table 5 documents (scaled grads beyond
    f16 range become inf, the step is skipped, Rust halves the scale).
    """
    def scaled_loss(p):
        return loss_fn(cfg, p, tokens) * loss_scale

    loss_s, grads = jax.value_and_grad(scaled_loss)(params)
    loss = loss_s / loss_scale
    if fp16_grads:
        grads = {k: g.astype(jnp.float16).astype(jnp.float32)
                 for k, g in grads.items()}
    grads = {k: g / loss_scale for k, g in grads.items()}

    finite = jnp.array(True)
    for g in grads.values():
        finite = jnp.logical_and(finite, jnp.all(jnp.isfinite(g)))

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.where(jnp.isfinite(g), g, 0.0) ** 2)
                         for g in grads.values()))

    new_step = step + 1.0
    bc1 = 1.0 - ADAM_B1 ** new_step
    bc2 = 1.0 - ADAM_B2 ** new_step

    new_p, new_m, new_v = {}, {}, {}
    for name in params:
        g = grads[name]
        mi = ADAM_B1 * m[name] + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * v[name] + (1.0 - ADAM_B2) * g * g
        update = (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        if _decay_mask(name):
            update = update + wd * params[name]
        pi = params[name] - lr * update
        # Skip the whole update when any grad overflowed (Table 5).
        new_p[name] = jnp.where(finite, pi, params[name])
        new_m[name] = jnp.where(finite, mi, m[name])
        new_v[name] = jnp.where(finite, vi, v[name])

    out_step = jnp.where(finite, new_step, step)
    return new_p, new_m, new_v, out_step, loss, gnorm, finite.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Activation capture (GPTQ calibration, §4.2)
# ---------------------------------------------------------------------------

def capture_linear_inputs(cfg: ModelConfig, params, tokens: jnp.ndarray):
    """Forward pass that also returns the input activations of every
    quantizable linear, in param_specs order. Used by the Rust GPTQ
    module to accumulate per-layer Hessians H = 2 X^T X.

    Returns a flat tuple: one (B*S, in_features) array per linear,
    ordered l0.attn_qkv-input, l0.attn_o-input, l0.mlp_gate/up-input,
    l0.mlp_down-input, l1..., i.e. 4 capture points per layer (q/k/v
    share their input, gate/up share theirs).
    """
    b, s = tokens.shape
    x = params["embed"][tokens]
    captures = []
    for l in range(cfg.layers):
        # attention
        xn = rmsnorm(x, params[f"l{l}.attn_norm"])
        x2 = xn.reshape(b * s, cfg.hidden)
        captures.append(x2)  # input of q, k, v
        q = (x2 @ params[f"l{l}.attn_q"].T).reshape(b, s, cfg.heads, cfg.head_dim)
        k = (x2 @ params[f"l{l}.attn_k"].T).reshape(b, s, cfg.heads, cfg.head_dim)
        v = (x2 @ params[f"l{l}.attn_v"].T).reshape(b, s, cfg.heads, cfg.head_dim)
        q, k = rope(q), rope(k)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(cfg.head_dim)
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, -1), v)
        ctx = ctx.reshape(b * s, cfg.hidden)
        captures.append(ctx)  # input of o
        x = x + (ctx @ params[f"l{l}.attn_o"].T).reshape(b, s, cfg.hidden)
        # mlp
        xn = rmsnorm(x, params[f"l{l}.mlp_norm"]).reshape(b * s, cfg.hidden)
        captures.append(xn)  # input of gate, up
        gate = xn @ params[f"l{l}.mlp_gate"].T
        up = xn @ params[f"l{l}.mlp_up"].T
        act = jax.nn.silu(gate) * up
        captures.append(act)  # input of down
        x = x + (act @ params[f"l{l}.mlp_down"].T).reshape(b, s, cfg.hidden)
    return tuple(captures)


CAPTURES_PER_LAYER = 4  # qkv-in, o-in, gate/up-in, down-in
