"""Pallas kernel for the BiLM binary linear layer (Appendix A.1 / B).

Binarization is centered-sign with a per-shard absmean scale of the
*centered* weights (see ref.py for the Table 1 typo note):

    mu    = mean(W_shard)
    alpha = eps + mean(|W_shard - mu|)
    W~    = alpha * sign(W - mu)

Like the ternary kernel, the per-shard statistics (mu, alpha) are tiny
global reductions computed outside the kernel and passed in as per-row
vectors so no block crosses a shard boundary.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling


def _binary_mm_kernel(x_ref, w_ref, mu_ref, a_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    centered = w_ref[...] - mu_ref[...]
    w_b = jnp.where(centered >= 0, 1.0, -1.0) * a_ref[...]
    o_ref[...] += jnp.dot(x_ref[...], w_b.T, preferred_element_type=jnp.float32)


def binary_stats(w: jnp.ndarray, mp: int):
    """Per-row (N,1) mu and alpha vectors from per-shard stats."""
    n = w.shape[0]
    shards = w.reshape(mp, n // mp, w.shape[1])
    mu = jnp.mean(shards, axis=(1, 2))
    alpha = 1e-5 + jnp.mean(jnp.abs(shards - mu[:, None, None]), axis=(1, 2))
    rep = n // mp
    return (jnp.repeat(mu, rep)[:, None], jnp.repeat(alpha, rep)[:, None])


def binary_matmul(x: jnp.ndarray, w: jnp.ndarray, mu_rows: jnp.ndarray,
                  a_rows: jnp.ndarray) -> jnp.ndarray:
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2
    bm, bn, bk = tiling.pick_blocks(m, n, k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _binary_mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, 1), lambda i, j, kk: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j, kk: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, mu_rows, a_rows)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def binary_linear(x: jnp.ndarray, w: jnp.ndarray, mp: int = 1) -> jnp.ndarray:
    """BiLM linear with STE gradients."""
    mu, a = binary_stats(w, mp)
    return binary_matmul(x, w, mu, a)


def _binary_linear_fwd(x, w, mp):
    mu, a = binary_stats(w, mp)
    y = binary_matmul(x, w, mu, a)
    w_b = jnp.where(w - mu >= 0, 1.0, -1.0) * a
    return y, (x, w_b)


def _binary_linear_bwd(mp, res, dy):
    x, w_b = res
    return dy @ w_b, dy.T @ x


binary_linear.defvjp(_binary_linear_fwd, _binary_linear_bwd)
