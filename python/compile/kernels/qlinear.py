"""Pallas kernel for the QuantLM k-bit group-dequant matmul (§4.2).

GPTQ stores each weight row as signed k-bit integers with one FP scale
per group of 128 input channels (symmetric, no zero offset — matching
the paper's Marlin-compatible format). The inference hot-spot is
dequantize-then-contract; the kernel stages the int tile and its scales
in VMEM, dequantizes there, and issues MXU-shaped dots.

K blocks are chosen as multiples of the group size so a block never
splits a quantization group.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling


def _qmm_kernel(group: int, x_ref, q_ref, s_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bn, bk = q_ref.shape
    ng = bk // group
    # (bn, ng, group) * (bn, ng, 1) -> dequantized (bn, bk)
    w = (q_ref[...].astype(jnp.float32).reshape(bn, ng, group)
         * s_ref[...][..., None]).reshape(bn, bk)
    o_ref[...] += jnp.dot(x_ref[...], w.T, preferred_element_type=jnp.float32)


def quant_matmul(x: jnp.ndarray, q: jnp.ndarray, scales: jnp.ndarray,
                 group: int = 128) -> jnp.ndarray:
    """y = x @ dequant(q, scales).T

    x: (M, K) f32; q: (N, K) int8 (k-bit values); scales: (N, K//group) f32.
    """
    m, k = x.shape
    n, k2 = q.shape
    group = min(group, k)
    assert k == k2 and k % group == 0
    bm = tiling.largest_divisor(m, tiling.DEFAULT_BM)
    bn = tiling.largest_divisor(n, tiling.DEFAULT_BN)
    # K blocks aligned to group boundaries.
    kg = k // group
    bkg = tiling.largest_divisor(kg, max(1, tiling.DEFAULT_BK // group))
    bk = bkg * group
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        lambda *refs: _qmm_kernel(group, *refs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bkg), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, q, scales)
