"""L1: Pallas kernels for the Spectra quantized-linear hot-spots.

- ternary:  TriLM on-the-fly ternarization matmul (+ inference variant)
- binary:   BiLM centered-sign matmul
- bitnet:   BitNet b1.58 fused norm + act-quant + ternary matmul
- qlinear:  QuantLM k-bit group-dequant matmul
- ref:      pure-jnp oracles for all of the above
"""

from . import binary, bitnet, qlinear, ref, ternary, tiling  # noqa: F401
