"""Pallas kernel for the BitNet-b1.58-style linear layer (§A.6).

BitNet differs from TriLM by (1) a parameterless RMSNorm immediately
before every linear, and (2) per-token 8-bit absmax quantization of the
input activations.  Both happen in-kernel on the activation tile: the
per-token statistics (rms, absmax) need the full K extent, so this
kernel requires bk == K (a single K block). Our model hidden sizes are
well within a VMEM tile, matching BitNet's own fused-kernel constraint.

Weights are ternarized on the fly exactly as in the TriLM kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling
from .ternary import gamma_rows

_EPS = 1e-5
_QMAX = 127.0


def _bitnet_mm_kernel(x_ref, w_ref, g_ref, o_ref):
    x = x_ref[...]
    # Parameterless RMSNorm over the (full-K) activation tile.
    x = x * (1.0 / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + _EPS))
    # 8-bit per-token absmax fake-quant.
    s = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True) / _QMAX, _EPS)
    x = jnp.round(jnp.clip(x / s, -_QMAX, _QMAX)) * s
    # Ternarize the weight tile and contract.
    g = g_ref[...]
    w_t = jnp.round(jnp.clip(w_ref[...] / g, -1.0, 1.0)) * g
    o_ref[...] = jnp.dot(x, w_t.T, preferred_element_type=jnp.float32)


def bitnet_matmul(x: jnp.ndarray, w: jnp.ndarray, g_rows: jnp.ndarray) -> jnp.ndarray:
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2
    bm = tiling.largest_divisor(m, tiling.DEFAULT_BM)
    bn = tiling.largest_divisor(n, tiling.DEFAULT_BN)
    grid = (m // bm, n // bn)  # full-K blocks: per-token stats need all of K
    return pl.pallas_call(
        _bitnet_mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, g_rows)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def bitnet_linear(x: jnp.ndarray, w: jnp.ndarray, mp: int = 1) -> jnp.ndarray:
    """BitNet b1.58 linear with STE through both quantizers."""
    return bitnet_matmul(x, w, gamma_rows(w, mp))


def _bitnet_fwd(x, w, mp):
    g = gamma_rows(w, mp)
    y = bitnet_matmul(x, w, g)
    # STE saves the normalized/quantized activations and dequantized
    # weights; the activation quant + norm gradient is passed through
    # (BitNet trains exactly this way).
    w_t = jnp.round(jnp.clip(w / g, -1.0, 1.0)) * g
    xn = x * (1.0 / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + _EPS))
    s = jnp.maximum(jnp.max(jnp.abs(xn), axis=-1, keepdims=True) / _QMAX, _EPS)
    xq = jnp.round(jnp.clip(xn / s, -_QMAX, _QMAX)) * s
    return y, (xq, w_t)


def _bitnet_bwd(mp, res, dy):
    xq, w_t = res
    return dy @ w_t, dy.T @ xq


bitnet_linear.defvjp(_bitnet_fwd, _bitnet_bwd)
