"""Pallas kernels for the TriLM ternary linear layer (§3.1, Table 1).

Two kernels:

- :func:`ternary_matmul` — the *training* forward hot-spot: ternarize the
  latent FP weights on the fly (round(clip(w/gamma, -1, 1)) * gamma, with
  per-model-parallel-shard gamma) and contract against the activations.
- :func:`ternary_matmul_infer` — the *inference* hot-spot: weights arrive
  already ternarized as {-1,0,+1} (stored packed on the Rust side and
  unpacked to int8 for execution); the kernel dequantizes in VMEM and
  contracts.

The scale reduction itself (absmean over each shard) is a tiny global
reduce and is computed outside the kernel (see ref.ternary_scales); the
kernels take a per-row gamma vector so the shard boundaries never cross a
block.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA
mental model (threadblock owns an output tile, streams K) is expressed
here as a (M/bm, N/bn, K/bk) grid whose BlockSpecs stage HBM->VMEM tiles,
with the contraction issued as an MXU-shaped `jnp.dot` in f32.

All pallas_calls use interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode lowers to plain HLO so the same graph
runs under the Rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling


def _ternary_mm_kernel(x_ref, w_ref, g_ref, o_ref):
    """Grid step: o[bm,bn] += x[bm,bk] @ ternarize(w[bn,bk]).T ."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[...]                                # (bn, 1) per-row scale
    w = w_ref[...]                                # (bn, bk) latent weights
    w_t = jnp.round(jnp.clip(w / g, -1.0, 1.0)) * g
    o_ref[...] += jnp.dot(x_ref[...], w_t.T, preferred_element_type=jnp.float32)


def _infer_mm_kernel(x_ref, q_ref, g_ref, o_ref):
    """Grid step with pre-ternarized int8 weights: dequant in VMEM."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w_t = q_ref[...].astype(jnp.float32) * g_ref[...]
    o_ref[...] += jnp.dot(x_ref[...], w_t.T, preferred_element_type=jnp.float32)


def _matmul_call(kernel, x, w, g_rows, w_dtype):
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2, f"contraction mismatch: {x.shape} vs {w.shape}"
    bm, bn, bk = tiling.pick_blocks(m, n, k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, 1), lambda i, j, kk: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w.astype(w_dtype), g_rows)


def gamma_rows(w: jnp.ndarray, mp: int) -> jnp.ndarray:
    """Per-row (N, 1) scale vector from per-shard absmean (§A.5)."""
    n = w.shape[0]
    shards = w.reshape(mp, n // mp, w.shape[1])
    gamma = 1e-5 + jnp.mean(jnp.abs(shards), axis=(1, 2))
    return jnp.repeat(gamma, n // mp)[:, None]


def ternary_matmul(x: jnp.ndarray, w: jnp.ndarray, g_rows: jnp.ndarray) -> jnp.ndarray:
    """y = x @ ternarize(w).T with on-the-fly ternarization.

    x: (M, K) f32; w: (N, K) f32 latent; g_rows: (N, 1) per-row gamma.
    """
    return _matmul_call(_ternary_mm_kernel, x, w, g_rows, jnp.float32)


def ternary_matmul_infer(x: jnp.ndarray, w_hat: jnp.ndarray,
                         g_rows: jnp.ndarray) -> jnp.ndarray:
    """y = x @ (gamma * w_hat).T with pre-ternarized int8 weight states."""
    return _matmul_call(_infer_mm_kernel, x, w_hat, g_rows, jnp.int8)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def ternary_linear(x: jnp.ndarray, w: jnp.ndarray, mp: int = 1) -> jnp.ndarray:
    """TriLM linear with straight-through-estimator gradients (Table 1).

    Forward: Y = X @ W~^T via the Pallas kernel.
    Backward: dX = dY @ W~ ; dW = dY^T @ X  (STE: grad w.r.t. the latent
    weights is the grad w.r.t. the ternarized weights, passed through).
    """
    return ternary_matmul(x, w, gamma_rows(w, mp))


def _ternary_linear_fwd(x, w, mp):
    g = gamma_rows(w, mp)
    y = ternary_matmul(x, w, g)
    # Save the *dequantized* weights for the backward contraction: Table 1
    # backprops through W~, not the latent W.
    w_t = jnp.round(jnp.clip(w / g, -1.0, 1.0)) * g
    return y, (x, w_t)


def _ternary_linear_bwd(mp, res, dy):
    x, w_t = res
    dx = dy @ w_t
    dw = dy.T @ x
    return dx, dw


ternary_linear.defvjp(_ternary_linear_fwd, _ternary_linear_bwd)
