"""Block-shape selection shared by the Pallas kernels.

The kernels tile for VMEM (§Hardware-Adaptation in DESIGN.md): each grid
step holds an (bm, bk) activation tile, a (bn, bk) weight tile and a
(bm, bn) accumulator tile resident in VMEM, targeting MXU-shaped
(128x128) dots.  Block shapes must divide the array dims exactly
(interpret-mode pallas does not pad), so we pick the largest divisor not
exceeding the target tile edge.
"""

from __future__ import annotations

# Tile-edge targets. On a real TPU these would be MXU-shaped (128) and
# VMEM-bounded; under interpret=True (CPU PJRT) each grid step lowers to
# a while-loop iteration with dynamic-slice staging, so fewer/larger
# tiles win: the §Perf pass measured 128/128/512 -> 512/512/1024 cutting
# TriLM train-step wall clock ~2x at the 15m size (see EXPERIMENTS.md
# §Perf). vmem_bytes()/mxu_utilization() report the TPU-shaped estimates
# for the DESIGN.md §Perf accounting.
DEFAULT_BM = 2048
DEFAULT_BN = 2048
DEFAULT_BK = 2048


def largest_divisor(dim: int, target: int) -> int:
    """Largest d <= target with dim % d == 0 (dim itself if dim <= target)."""
    if dim <= target:
        return dim
    for d in range(target, 0, -1):
        if dim % d == 0:
            return d
    return 1


def pick_blocks(m: int, n: int, k: int,
                bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                bk: int = DEFAULT_BK) -> tuple[int, int, int]:
    return (largest_divisor(m, bm), largest_divisor(n, bn),
            largest_divisor(k, bk))


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint of one grid step (x + w + out tiles)."""
    return (bm * bk + bn * bk + bm * bn) * dtype_bytes


def mxu_utilization(bm: int, bn: int, bk: int) -> float:
    """Fraction of a 128x128x128 MXU pass filled by the chosen tiles."""
    return (min(bm, 128) / 128.0) * (min(bn, 128) / 128.0) * (min(bk, 128) / 128.0)
