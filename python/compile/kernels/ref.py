"""Pure-jnp oracles for the quantized linear layers (Table 1 of the paper).

These are the correctness references the Pallas kernels are tested
against (python/tests/test_kernels.py). They implement the forward-pass
equations of Table 1 for TriLM, BiLM and the k-bit group-quantized
QuantLM dequant path, plus BitNet b1.58's activation quantization.

Notational note: Table 1 prints the TriLM scale as
``gamma = eps + mean(W)`` and the BiLM scale as ``alpha = mean(W)``;
both are typos for the *absolute* mean (the text of §3.1 says "the
scale value to the absolute mean of the latent weights", matching
BitNet b1.58). We implement the absmean forms.
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-5


# ---------------------------------------------------------------------------
# TriLM (ternary) — §3.1 / Table 1
# ---------------------------------------------------------------------------

def ternary_scales(w: jnp.ndarray, mp: int = 1) -> jnp.ndarray:
    """Per-model-parallel-shard absmean scales, shape (mp,).

    ``w`` is (out_features, in_features).  Megatron-style column
    parallelism shards the output dimension across ``mp`` devices; each
    device computes its own scale over its local shard (§A.5), which is
    what introduces the "mp scalar values per matrix" artifact.
    """
    out = w.shape[0]
    assert out % mp == 0, f"out={out} not divisible by mp={mp}"
    shards = w.reshape(mp, out // mp, w.shape[1])
    return EPS + jnp.mean(jnp.abs(shards), axis=(1, 2))


def ternarize(w: jnp.ndarray, mp: int = 1):
    """Round latent weights to {-1, 0, +1} per shard. Returns (w_hat, gamma).

    w_hat has the same shape as w with values in {-1, 0, 1};
    gamma has shape (mp,).
    """
    gamma = ternary_scales(w, mp)
    g = jnp.repeat(gamma, w.shape[0] // mp)[:, None]
    w_hat = jnp.round(jnp.clip(w / g, -1.0, 1.0))
    return w_hat, gamma


def ternary_dequant(w_hat: jnp.ndarray, gamma: jnp.ndarray) -> jnp.ndarray:
    """W~ = gamma * w_hat with per-shard gamma broadcast over rows."""
    g = jnp.repeat(gamma, w_hat.shape[0] // gamma.shape[0])[:, None]
    return g * w_hat


def ternary_linear(x: jnp.ndarray, w: jnp.ndarray, mp: int = 1) -> jnp.ndarray:
    """Forward pass: Y = X @ W~^T with on-the-fly ternarization."""
    w_hat, gamma = ternarize(w, mp)
    return x @ ternary_dequant(w_hat, gamma).T


# ---------------------------------------------------------------------------
# BiLM (binary) — Appendix A.1 / B
# ---------------------------------------------------------------------------

def binarize(w: jnp.ndarray, mp: int = 1):
    """Centered sign binarization with per-shard absmean scale.

    alpha is the absmean of the centered shard (BitNet's binarization;
    see the module docstring for the Table 1 typo).
    Returns (w_hat in {-1, +1}, alpha shape (mp,)).
    """
    out = w.shape[0]
    shards = w.reshape(mp, out // mp, w.shape[1])
    mean = jnp.mean(shards, axis=(1, 2), keepdims=True)
    centered = shards - mean
    alpha = EPS + jnp.mean(jnp.abs(centered), axis=(1, 2))
    w_hat = jnp.where(centered >= 0, 1.0, -1.0).reshape(w.shape)
    return w_hat, alpha


def binary_linear(x: jnp.ndarray, w: jnp.ndarray, mp: int = 1) -> jnp.ndarray:
    w_hat, alpha = binarize(w, mp)
    a = jnp.repeat(alpha, w.shape[0] // mp)[:, None]
    return x @ (a * w_hat).T


# ---------------------------------------------------------------------------
# BitNet b1.58-style activation quantization (§A.6)
# ---------------------------------------------------------------------------

def absmax_quant_act(x: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """Per-token absmax activation quantization to ``bits`` (dequantized).

    BitNet quantizes the input activations of every linear to 8 bits
    with a per-token absmax scale; this returns the fake-quantized
    (quantize->dequantize) activations used in the forward pass.
    """
    qmax = 2.0 ** (bits - 1) - 1.0
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qmax
    scale = jnp.maximum(scale, EPS)
    return jnp.round(jnp.clip(x / scale, -qmax, qmax)) * scale


def parameterless_rmsnorm(x: jnp.ndarray) -> jnp.ndarray:
    """BitNet's scale-free RMSNorm applied before each linear."""
    return x * (1.0 / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS))


def bitnet_linear(x: jnp.ndarray, w: jnp.ndarray, mp: int = 1) -> jnp.ndarray:
    """BitNet b1.58 linear: norm + 8-bit act quant + ternary weights."""
    xq = absmax_quant_act(parameterless_rmsnorm(x))
    return ternary_linear(xq, w, mp)


# ---------------------------------------------------------------------------
# QuantLM k-bit symmetric group quantization (GPTQ storage format, §4.2)
# ---------------------------------------------------------------------------

def group_quant(w: jnp.ndarray, bits: int, group: int = 128):
    """Symmetric (no zero offset) per-group quantization of rows.

    Rows of ``w`` (out, in) are split into groups of ``group`` input
    channels; each group gets an absmax scale mapping to the signed
    ``bits``-bit integer grid. Returns (q int32, scales (out, n_groups)).
    """
    out, k = w.shape
    group = min(group, k)
    assert k % group == 0, f"in_features={k} not divisible by group={group}"
    ng = k // group
    wg = w.reshape(out, ng, group)
    qmax = 2.0 ** (bits - 1) - 1.0
    scales = jnp.max(jnp.abs(wg), axis=-1) / qmax
    scales = jnp.maximum(scales, EPS)
    q = jnp.round(jnp.clip(wg / scales[..., None], -qmax, qmax)).astype(jnp.int32)
    return q, scales


def group_dequant(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    out, ng, group = q.shape
    return (q.astype(jnp.float32) * scales[..., None]).reshape(out, ng * group)


def quant_linear(x: jnp.ndarray, q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """Forward with dequantized k-bit weights: Y = X @ dequant(q)^T."""
    return x @ group_dequant(q, scales).T
