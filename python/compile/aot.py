"""AOT lowering: JAX graphs -> HLO text artifacts + manifest.json.

This is the only place Python touches the model after development: every
graph the Rust coordinator needs is lowered here once (`make artifacts`)
and executed from Rust via PJRT forever after.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Build plan (mirrors the paper's suite scope):
  - FloatLM + TriLM at every suite size (train, eval, next_logits)
  - BiLM at three sizes (App. B trains three BiLMs)
  - BitNet replication at one size (§A.6 replicates one BitNet)
  - fp16-grad train variants for the loss-scaling study (Table 5)
  - activation-capture graphs for FloatLM (GPTQ calibration, §4.2)

Calling convention (shared with rust/src/runtime/manifest.rs): inputs
and outputs are flat lists of arrays; parameter order is
model.param_specs order. The manifest records every graph's file name,
input/output specs, and the model config.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

TRAIN_BATCH = 8
EVAL_BATCH = 8
CAPTURE_BATCH = 4

# Paper scope mapping: which families get which sizes.
BINARY_SIZES = ("160k", "930k", "6.7m")
BITNET_SIZES = ("930k",)
FP16_SIZES = ("160k", "430k", "930k")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def _params_as_list(cfg):
    """abstract args for lowering, in param_specs order."""
    return [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in M.param_specs(cfg)]


def _dict_from_list(cfg, flat):
    names = [n for n, _ in M.param_specs(cfg)]
    return dict(zip(names, flat))


def _list_from_dict(cfg, d):
    return [d[n] for n, _ in M.param_specs(cfg)]


def lower_train(cfg, batch, fp16_grads):
    P = len(M.param_specs(cfg))

    def fn(*args):
        params = _dict_from_list(cfg, args[:P])
        m = _dict_from_list(cfg, args[P:2 * P])
        v = _dict_from_list(cfg, args[2 * P:3 * P])
        step, tokens, lr, wd, loss_scale = args[3 * P:]
        p2, m2, v2, step2, loss, gnorm, finite = M.train_step(
            cfg, fp16_grads, params, m, v, step, tokens, lr, wd, loss_scale)
        return tuple(_list_from_dict(cfg, p2) + _list_from_dict(cfg, m2)
                     + _list_from_dict(cfg, v2) + [step2, loss, gnorm, finite])

    scal = jax.ShapeDtypeStruct((), jnp.float32)
    toks = jax.ShapeDtypeStruct((batch, cfg.seq + 1), jnp.int32)
    args = (_params_as_list(cfg) * 3) + [scal, toks, scal, scal, scal]
    return jax.jit(fn, keep_unused=True).lower(*args)


def lower_eval(cfg, batch):
    def fn(*args):
        params = _dict_from_list(cfg, args[:-1])
        return (M.token_logprobs(cfg, params, args[-1]),)

    toks = jax.ShapeDtypeStruct((batch, cfg.seq + 1), jnp.int32)
    return jax.jit(fn, keep_unused=True).lower(*(_params_as_list(cfg) + [toks]))


def lower_next_logits(cfg, batch):
    def fn(*args):
        params = _dict_from_list(cfg, args[:-1])
        logits = M.forward(cfg, params, args[-1])
        return (logits[:, -1, :],)

    toks = jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32)
    return jax.jit(fn, keep_unused=True).lower(*(_params_as_list(cfg) + [toks]))


def lower_capture(cfg, batch):
    def fn(*args):
        params = _dict_from_list(cfg, args[:-1])
        return M.capture_linear_inputs(cfg, params, args[-1])

    toks = jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32)
    return jax.jit(fn, keep_unused=True).lower(*(_params_as_list(cfg) + [toks]))


def build_plan(sizes, families):
    """(size, family, graph, lower_fn) entries for the artifact build."""
    plan = []
    for size in sizes:
        for family in families:
            if family == "binary" and size not in BINARY_SIZES:
                continue
            if family == "bitnet" and size not in BITNET_SIZES:
                continue
            cfg = M.suite_config(size, family)
            plan.append((cfg, "train",
                         lambda c=cfg: lower_train(c, TRAIN_BATCH, False)))
            plan.append((cfg, "eval",
                         lambda c=cfg: lower_eval(c, EVAL_BATCH)))
            plan.append((cfg, "next_logits",
                         lambda c=cfg: lower_next_logits(c, 1)))
            if family in ("float", "ternary") and size in FP16_SIZES:
                plan.append((cfg, "train_fp16",
                             lambda c=cfg: lower_train(c, TRAIN_BATCH, True)))
            if family == "float":
                plan.append((cfg, "capture",
                             lambda c=cfg: lower_capture(c, CAPTURE_BATCH)))
    return plan


def graph_io_spec(cfg, graph):
    """Input/output array specs for the manifest (rust sanity checks)."""
    P = len(M.param_specs(cfg))
    pspecs = [_spec(s) for _, s in M.param_specs(cfg)]
    scal = _spec(())
    if graph in ("train", "train_fp16"):
        toks = _spec((TRAIN_BATCH, cfg.seq + 1), "s32")
        return (pspecs * 3 + [scal, toks, scal, scal, scal],
                pspecs * 3 + [scal, scal, scal, scal])
    if graph == "eval":
        toks = _spec((EVAL_BATCH, cfg.seq + 1), "s32")
        return (pspecs + [toks], [_spec((EVAL_BATCH, cfg.seq))])
    if graph == "next_logits":
        toks = _spec((1, cfg.seq), "s32")
        return (pspecs + [toks], [_spec((1, cfg.vocab))])
    if graph == "capture":
        toks = _spec((CAPTURE_BATCH, cfg.seq), "s32")
        rows = CAPTURE_BATCH * cfg.seq
        outs = []
        for _ in range(cfg.layers):
            outs += [_spec((rows, cfg.hidden)), _spec((rows, cfg.hidden)),
                     _spec((rows, cfg.hidden)), _spec((rows, cfg.glu))]
        return (pspecs + [toks], outs)
    raise ValueError(graph)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default=",".join(M.SUITE))
    ap.add_argument("--families", default="float,ternary,binary,bitnet")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the artifact file exists")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    sizes = [s for s in args.sizes.split(",") if s]
    families = [f for f in args.families.split(",") if f]

    manifest = {
        "seq": 128,
        "train_batch": TRAIN_BATCH,
        "eval_batch": EVAL_BATCH,
        "capture_batch": CAPTURE_BATCH,
        "adam": {"b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS},
        "models": {},
    }
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    if os.path.exists(manifest_path) and not args.force:
        with open(manifest_path) as f:
            manifest["models"] = json.load(f).get("models", {})

    plan = build_plan(sizes, families)
    t_all = time.time()
    for cfg, graph, lower in plan:
        key = cfg.name
        entry = manifest["models"].setdefault(key, {
            "size": key.split("_")[0],
            "family": cfg.family,
            "config": {k: getattr(cfg, k) for k in
                       ("vocab", "hidden", "glu", "heads", "layers",
                        "seq", "mp", "family")},
            "n_params": M.n_params(cfg),
            "params": [{"name": n, "shape": list(s)}
                       for n, s in M.param_specs(cfg)],
            "graphs": {},
        })
        fname = f"{key}_{graph}.hlo.txt"
        fpath = os.path.join(args.out_dir, fname)
        if os.path.exists(fpath) and graph in entry["graphs"] and not args.force:
            continue
        t0 = time.time()
        text = to_hlo_text(lower())
        with open(fpath, "w") as f:
            f.write(text)
        ins, outs = graph_io_spec(cfg, graph)
        entry["graphs"][graph] = {"file": fname, "inputs": ins, "outputs": outs}
        print(f"lowered {key}/{graph}: {len(text) / 1e6:.1f} MB "
              f"in {time.time() - t0:.1f}s", flush=True)
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"artifacts complete in {time.time() - t_all:.1f}s "
          f"({len(manifest['models'])} models)")


if __name__ == "__main__":
    main()
